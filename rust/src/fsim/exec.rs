//! Functional (tensor-level) execution of a compiled program image.
//!
//! The cycle-level SoC interprets the RV32IM+CIM instruction stream one
//! step at a time (~10^6 steps per KWS inference). This module instead
//! executes the *same deployable artifact* — the linked [`Program`] — at
//! the op level: it decodes the DRAM weight streams back into per-layer
//! sign matrices (the inverse of `KwsPlan::build_dram_weights`), reads the
//! folded-BN threshold/flip tables out of the DMEM image, and then runs
//! the shared quantized kernels (`model::reference`) over them. Because
//! both engines bottom out in the same integer semantics — the macro's
//! `2*pop(x&sign&mask) - pop(x&mask)` MAC equals the reference conv — the
//! logits are bit-identical to the cycle simulator's (asserted by
//! `rust/tests/backend_parity.rs`).
//!
//! Nothing here consults the source `KwsModel`: if the compiler or weight
//! streaming were wrong, fsim would disagree with the host reference, so
//! the decode path doubles as a check on the program image itself.

use anyhow::{anyhow, ensure, Result};

use crate::compiler::Program;
use crate::dataflow::plan;
use crate::model::kws::LayerSpec;
use crate::model::reference::{self, BitMap};

/// A program image decoded back to tensor-level form.
#[derive(Debug, Clone)]
pub struct DecodedProgram {
    /// Per-layer specs reconstructed from the DRAM sign/threshold streams.
    pub layers: Vec<LayerSpec>,
    /// Folded-BN feature thresholds (DMEM table, one i32 per channel).
    pub thr: Vec<i32>,
    /// Per-word flip masks applied to each packed feature word.
    pub flip: Vec<u32>,
    /// Input feature-map geometry.
    pub t: usize,
    pub c: usize,
    pub audio_len: usize,
    pub n_classes: usize,
    pub final_t: usize,
}

fn le_u32(bytes: &[u8], word: usize) -> u32 {
    let i = word * 4;
    u32::from_le_bytes([bytes[i], bytes[i + 1], bytes[i + 2], bytes[i + 3]])
}

fn dmem_chunk(program: &Program, off: u32) -> Result<&Vec<u32>> {
    program
        .dmem
        .iter()
        .find(|(o, _)| *o == off)
        .map(|(_, w)| w)
        .ok_or_else(|| anyhow!("DMEM table at {off:#x} missing from image"))
}

impl DecodedProgram {
    /// Decode a compiled image. Fails loudly if the image is not a KWS
    /// program in the shape the row-wise dataflow compiler emits.
    pub fn decode(program: &Program) -> Result<Self> {
        let p = &program.plan;
        ensure!(!p.layers.is_empty(), "program plan has no layers");
        let t = p.layers[0].t_in;
        let c = p.layers[0].s_words * 32;
        let audio_len = p.audio_bytes as usize / 2;

        // DMEM constant tables: thresholds then flip words.
        let thr_words = dmem_chunk(program, plan::DMEM_THR)?;
        let flip_words = dmem_chunk(program, plan::DMEM_FLIP)?;
        ensure!(thr_words.len() == c, "threshold table length {} != c {c}", thr_words.len());
        ensure!(flip_words.len() == c / 32, "flip table length");
        let thr: Vec<i32> = thr_words.iter().map(|&w| w as i32).collect();
        let flip = flip_words.clone();

        // Per-layer weight streams: sign words (column-major bursts) then
        // threshold words, exactly as `build_dram_weights` laid them out.
        let mut layers = Vec::with_capacity(p.layers.len());
        for lp in &p.layers {
            let bytes = program
                .dram
                .iter()
                .find(|(off, _)| *off == lp.dram_offset)
                .map(|(_, b)| b)
                .ok_or_else(|| {
                    anyhow!("layer {} weight stream missing from DRAM image", lp.index)
                })?;
            ensure!(
                bytes.len() == (lp.sign_words + lp.th_words) * 4,
                "layer {}: stream is {} bytes, want {}",
                lp.index,
                bytes.len(),
                (lp.sign_words + lp.th_words) * 4
            );
            let aw = lp.window_words;
            let c_in = lp.s_words * 32;
            ensure!(aw * 32 % c_in == 0, "layer {}: window not a whole kernel", lp.index);
            let kernel = aw * 32 / c_in;
            ensure!(kernel == 3, "fsim supports the paper's k=3 row-wise dataflow");
            let rows = aw * 32;

            // Sign bit set -> +1, clear -> -1 (the boot sequence arms the
            // whole mask plane, so every cell is active: binary weights).
            let mut weights = vec![-1i8; rows * lp.c_out];
            for co in 0..lp.c_out {
                for wj in 0..aw {
                    let sign = le_u32(bytes, co * aw + wj);
                    for b in 0..32 {
                        if (sign >> b) & 1 == 1 {
                            weights[(wj * 32 + b) * lp.c_out + co] = 1;
                        }
                    }
                }
            }
            let thresholds: Vec<i32> = if lp.binarized {
                (0..lp.th_words).map(|j| le_u32(bytes, lp.sign_words + j) as i32).collect()
            } else {
                Vec::new()
            };
            layers.push(LayerSpec {
                c_in,
                c_out: lp.c_out,
                kernel,
                pooled: lp.pooled,
                binarized: lp.binarized,
                weights,
                thresholds,
            });
        }
        ensure!(
            layers[..layers.len() - 1].iter().all(|l| l.binarized),
            "only the final layer may be raw"
        );
        ensure!(!layers.last().unwrap().binarized, "final layer must be raw (GAP path)");

        Ok(DecodedProgram {
            layers,
            thr,
            flip,
            t,
            c,
            audio_len,
            n_classes: program.n_classes,
            final_t: program.final_t,
        })
    }

    /// Integer preprocessing from the image's DMEM tables — the same
    /// pre-emphasis / magnitude / threshold-compare / flip pipeline the
    /// emitted RISC-V code runs, over the quantized ADC samples.
    pub fn preprocess(&self, audio: &[f32]) -> BitMap {
        let q = reference::quantize_audio(audio);
        let frame = self.audio_len / self.t;
        let mut bits = BitMap::zero(self.t, self.c);
        for t in 0..self.t {
            for ch in 0..self.c {
                let idx = t * frame + ch;
                let x = q.get(idx).copied().unwrap_or(0);
                let prev = if idx == 0 { 0 } else { q.get(idx - 1).copied().unwrap_or(0) };
                // y = 32x - 31*prev; |y| <= 32*2048 + 31*2048, fits i32.
                let f = (32 * x - 31 * prev).abs();
                let flipped = (self.flip[ch / 32] >> (ch % 32)) & 1 == 1;
                if (self.thr[ch] < f) != flipped {
                    bits.set(t, ch);
                }
            }
        }
        bits
    }

    /// Full inference: audio -> (logits, argmax). Runs the shared
    /// quantized kernels over the decoded layers.
    pub fn infer(&self, audio: &[f32]) -> (Vec<f32>, usize) {
        let mut x = self.preprocess(audio);
        for spec in &self.layers[..self.layers.len() - 1] {
            x = reference::conv_layer(&x, spec);
        }
        let logits = reference::final_layer_gap(&x, self.layers.last().unwrap());
        let predicted = reference::argmax(&logits);
        (logits, predicted)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::OptLevel;
    use crate::compiler::build_kws_program;
    use crate::model::{dataset, KwsModel};

    #[test]
    fn decode_recovers_layer_geometry() {
        let m = KwsModel::synthetic(11);
        let prog = build_kws_program(&m, OptLevel::FULL).unwrap();
        let d = DecodedProgram::decode(&prog).unwrap();
        assert_eq!(d.layers.len(), m.layers.len());
        assert_eq!(d.t, m.t);
        assert_eq!(d.c, m.c);
        assert_eq!(d.n_classes, m.n_classes);
        for (got, want) in d.layers.iter().zip(&m.layers) {
            assert_eq!(got.c_in, want.c_in);
            assert_eq!(got.c_out, want.c_out);
            assert_eq!(got.kernel, want.kernel);
            assert_eq!(got.pooled, want.pooled);
            assert_eq!(got.binarized, want.binarized);
            // Binary models round-trip through the sign stream exactly.
            assert_eq!(got.weights, want.weights);
            assert_eq!(got.thresholds, want.thresholds);
        }
    }

    #[test]
    fn decoded_inference_matches_host_reference() {
        let m = KwsModel::synthetic(5);
        let prog = build_kws_program(&m, OptLevel::FULL).unwrap();
        let d = DecodedProgram::decode(&prog).unwrap();
        for seed in 0..3u64 {
            let audio = dataset::synth_utterance(seed as usize % 12, seed, m.audio_len, 0.3);
            let (logits, predicted) = d.infer(&audio);
            let want = crate::model::reference::infer(&m, &audio);
            assert_eq!(logits, want, "seed {seed}");
            assert_eq!(predicted, crate::model::reference::argmax(&want));
        }
    }

    #[test]
    fn opt_level_never_changes_decoded_values() {
        let m = KwsModel::synthetic(2);
        let audio = dataset::synth_utterance(4, 9, m.audio_len, 0.3);
        let mut logits: Option<Vec<f32>> = None;
        for (name, opt) in OptLevel::ladder() {
            let prog = build_kws_program(&m, opt).unwrap();
            let (l, _) = DecodedProgram::decode(&prog).unwrap().infer(&audio);
            if let Some(prev) = &logits {
                assert_eq!(&l, prev, "{name} changed logits");
            }
            logits = Some(l);
        }
    }
}
