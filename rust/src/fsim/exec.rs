//! Functional (tensor-level) execution of a compiled program image.
//!
//! The cycle-level SoC interprets the RV32IM+CIM instruction stream one
//! step at a time (~10^6 steps per KWS inference). This module instead
//! executes the *same deployable artifact* — the linked [`Program`] — at
//! the op level, and in the macro's own representation: the DRAM sign
//! stream the compiler emits (`KwsPlan::build_dram_weights`, column-major
//! sign words) *is already* the [`PackedLayer`] bit-plane form, so decode
//! is a word copy, not an unpack, and inference runs the XNOR-popcount
//! kernels (`model::reference::conv_layer_packed`) directly over it —
//! `2*pop(x & sign) - pop(x)`, the same MAC the macro fires. Because both
//! engines bottom out in identical integer semantics, the logits are
//! bit-identical to the cycle simulator's (asserted by
//! `rust/tests/backend_parity.rs`).
//!
//! The PR 1 scalar path (per-bit preprocess + per-channel i8 conv loops)
//! is kept reachable through [`DecodedProgram::to_layer_specs`] /
//! [`DecodedProgram::infer_scalar`] as the oracle and the benchmark
//! baseline (`benches/backend_throughput.rs`).
//!
//! Nothing here consults the source `KwsModel`: if the compiler or weight
//! streaming were wrong, fsim would disagree with the host reference, so
//! the decode path doubles as a check on the program image itself.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Barrier, Mutex, RwLock};

use anyhow::{anyhow, bail, ensure, Result};

use crate::compiler::Program;
use crate::dataflow::plan;
use crate::dataflow::shard::{ShardAxis, ShardPlan};
use crate::model::kernel::{self, LaneLayer};
use crate::model::kws::LayerSpec;
use crate::model::reference::{self, BitMap, PackedLayer};
use crate::telemetry::profiler::layer_name;
use crate::telemetry::region;
use crate::util::{lock_or_recover, read_or_recover, write_or_recover};

/// A program image decoded back to tensor-level form.
#[derive(Debug, Clone)]
pub struct DecodedProgram {
    /// Per-layer sign bit-planes, copied straight out of the DRAM weight
    /// streams (the stream layout and the plane layout coincide; pairs of
    /// u32 stream words fold into the u64 window words the kernels use).
    pub layers: Vec<PackedLayer>,
    /// The same planes transposed into the lane-blocked engine form
    /// (`model::kernel::LaneLayer`) — what [`Self::infer`] and the
    /// batched/sharded paths actually run on. `layers` stays the
    /// oracle/replay representation.
    pub lanes: Vec<LaneLayer>,
    /// Folded-BN feature thresholds (DMEM table, one i32 per channel).
    pub thr: Vec<i32>,
    /// Per-word flip masks applied to each packed feature word.
    pub flip: Vec<u32>,
    /// Input feature-map geometry.
    pub t: usize,
    pub c: usize,
    pub audio_len: usize,
    pub n_classes: usize,
    pub final_t: usize,
}

/// A decoded program pre-sliced for multi-macro execution: per macro,
/// per layer, the channel offset and the sub-[`PackedLayer`] that macro
/// owns (`None` where the split leaves a macro idle for that layer).
#[derive(Debug, Clone)]
pub struct ShardedProgram {
    /// Macro count (shard plan's `n_macros`).
    pub n: usize,
    /// `per_macro[m][layer] = Some((channel offset, shard))` in the
    /// packed-plane form — the representation the variation-aware replay
    /// (`robustness::replay`) walks fire by fire; keep its shape stable.
    pub per_macro: Vec<Vec<Option<(usize, PackedLayer)>>>,
    /// The same shards transposed for the lane engine (what the sharded
    /// inference paths execute).
    pub lane_per_macro: Vec<Vec<Option<(usize, LaneLayer)>>>,
    /// Fires each macro performs per inference (one per row position of
    /// every layer it owns channels of) — the per-shard utilization
    /// surfaced by `ServiceStats` and the coordinator report.
    pub fires_per_macro: Vec<u64>,
}

/// Best-effort message out of a caught panic payload (shard-thread death
/// reporting; `&str` and `String` cover `panic!` and `assert!`).
fn panic_msg(p: &(dyn std::any::Any + Send)) -> &str {
    p.downcast_ref::<&str>()
        .copied()
        .or_else(|| p.downcast_ref::<String>().map(String::as_str))
        .unwrap_or("non-string panic payload")
}

/// `x` with only input channels `[c0, c1)` retained (both bounds are
/// 32-multiples by input-plan construction, so this is a word copy).
/// The map keeps its full width: `conv_sums_packed_into` windows then
/// align with the full sign planes, and the zeroed words contribute
/// nothing to `pop(win)` or `pop(win & plane)` — exactly the partial a
/// macro holding that input slice computes.
fn mask_to_input_slice(x: &BitMap, c0: usize, c1: usize) -> BitMap {
    debug_assert!(c0 % 32 == 0 && c1 % 32 == 0 && c0 <= c1 && c1 <= x.c);
    let wpr = x.wpr();
    let (w0, w1) = (c0 / 32, c1 / 32);
    let mut m = BitMap::zero(x.t, x.c);
    for t in 0..x.t {
        let row = t * wpr;
        m.words[row + w0..row + w1].copy_from_slice(&x.words[row + w0..row + w1]);
    }
    m
}

fn le_u32(bytes: &[u8], word: usize) -> u32 {
    let i = word * 4;
    u32::from_le_bytes([bytes[i], bytes[i + 1], bytes[i + 2], bytes[i + 3]])
}

fn dmem_chunk(program: &Program, off: u32) -> Result<&Vec<u32>> {
    program
        .dmem
        .iter()
        .find(|(o, _)| *o == off)
        .map(|(_, w)| w)
        .ok_or_else(|| anyhow!("DMEM table at {off:#x} missing from image"))
}

impl DecodedProgram {
    /// Decode a compiled image. Fails loudly if the image is not a KWS
    /// program in the shape the row-wise dataflow compiler emits.
    pub fn decode(program: &Program) -> Result<Self> {
        let p = &program.plan;
        ensure!(!p.layers.is_empty(), "program plan has no layers");
        let t = p.layers[0].t_in;
        let c = p.layers[0].s_words * 32;
        let audio_len = p.audio_bytes as usize / 2;

        // DMEM constant tables: thresholds then flip words.
        let thr_words = dmem_chunk(program, plan::DMEM_THR)?;
        let flip_words = dmem_chunk(program, plan::DMEM_FLIP)?;
        ensure!(thr_words.len() == c, "threshold table length {} != c {c}", thr_words.len());
        ensure!(flip_words.len() == c / 32, "flip table length");
        let thr: Vec<i32> = thr_words.iter().map(|&w| w as i32).collect();
        let flip = flip_words.clone();

        // Per-layer weight streams: sign words (column-major bursts) then
        // threshold words, exactly as `build_dram_weights` laid them out.
        // The sign bits need no reordering — `co * aw + wj` stream order
        // is the PackedLayer plane layout (bit set -> +1; the boot
        // sequence arms the whole mask plane, so every cell is active:
        // binary weights). Each pair of consecutive u32 stream words
        // folds into one u64 window word (little-endian halves), the
        // widened form the popcount kernels run over.
        let mut layers = Vec::with_capacity(p.layers.len());
        for lp in &p.layers {
            let bytes = program
                .dram
                .iter()
                .find(|(off, _)| *off == lp.dram_offset)
                .map(|(_, b)| b)
                .ok_or_else(|| {
                    anyhow!("layer {} weight stream missing from DRAM image", lp.index)
                })?;
            ensure!(
                bytes.len() == (lp.sign_words + lp.th_words) * 4,
                "layer {}: stream is {} bytes, want {}",
                lp.index,
                bytes.len(),
                (lp.sign_words + lp.th_words) * 4
            );
            let aw = lp.window_words;
            let c_in = lp.s_words * 32;
            ensure!(aw * 32 % c_in == 0, "layer {}: window not a whole kernel", lp.index);
            let kernel = aw * 32 / c_in;
            ensure!(kernel == 3, "fsim supports the paper's k=3 row-wise dataflow");

            let pw = aw.div_ceil(2); // u64 words per plane == ceil(rows/64)
            let mut planes = vec![0u64; lp.c_out * pw];
            for co in 0..lp.c_out {
                for wj in 0..aw {
                    let word = le_u32(bytes, co * aw + wj) as u64;
                    planes[co * pw + wj / 2] |= word << (32 * (wj % 2));
                }
            }
            let thresholds: Vec<i32> = if lp.binarized {
                (0..lp.th_words).map(|j| le_u32(bytes, lp.sign_words + j) as i32).collect()
            } else {
                Vec::new()
            };
            layers.push(PackedLayer {
                c_in,
                c_out: lp.c_out,
                kernel,
                pooled: lp.pooled,
                binarized: lp.binarized,
                plane_words: pw,
                planes,
                thresholds,
            });
        }
        ensure!(
            layers[..layers.len() - 1].iter().all(|l| l.binarized),
            "only the final layer may be raw"
        );
        ensure!(!layers.last().unwrap().binarized, "final layer must be raw (GAP path)");

        let lanes = layers.iter().map(LaneLayer::from_packed).collect();
        Ok(DecodedProgram {
            layers,
            lanes,
            thr,
            flip,
            t,
            c,
            audio_len,
            n_classes: program.n_classes,
            final_t: program.final_t,
        })
    }

    /// Integer preprocessing from the image's DMEM tables — the same
    /// pre-emphasis / magnitude / threshold-compare / flip pipeline the
    /// emitted RISC-V code runs, vectorized: one frame's magnitudes in a
    /// single pass, then 32 channel compares per packed word with the
    /// flip word applied by XOR (decoded `c` is always a word multiple).
    pub fn preprocess(&self, audio: &[f32]) -> BitMap {
        let _r = region("preprocess");
        let q = reference::quantize_audio(audio);
        let frame = self.audio_len / self.t;
        let mut bits = BitMap::zero(self.t, self.c);
        let wpr = bits.wpr();
        let mut mags = vec![0i32; self.c];
        for t in 0..self.t {
            let base = t * frame;
            for (ch, m) in mags.iter_mut().enumerate() {
                let idx = base + ch;
                let x = q.get(idx).copied().unwrap_or(0);
                let prev = if idx == 0 { 0 } else { q.get(idx - 1).copied().unwrap_or(0) };
                // y = 32x - 31*prev; |y| <= 32*2048 + 31*2048, fits i32.
                *m = (32 * x - 31 * prev).abs();
            }
            for wi in 0..wpr {
                let mut word = 0u32;
                for b in 0..32 {
                    if self.thr[wi * 32 + b] < mags[wi * 32 + b] {
                        word |= 1 << b;
                    }
                }
                bits.words[t * wpr + wi] = word ^ self.flip[wi];
            }
        }
        bits
    }

    /// Bit-at-a-time preprocessing (the PR 1 form): the oracle for the
    /// vectorized [`Self::preprocess`] and the benchmark baseline.
    pub fn preprocess_scalar(&self, audio: &[f32]) -> BitMap {
        let q = reference::quantize_audio(audio);
        let frame = self.audio_len / self.t;
        let mut bits = BitMap::zero(self.t, self.c);
        for t in 0..self.t {
            for ch in 0..self.c {
                let idx = t * frame + ch;
                let x = q.get(idx).copied().unwrap_or(0);
                let prev = if idx == 0 { 0 } else { q.get(idx - 1).copied().unwrap_or(0) };
                let f = (32 * x - 31 * prev).abs();
                let flipped = (self.flip[ch / 32] >> (ch % 32)) & 1 == 1;
                if (self.thr[ch] < f) != flipped {
                    bits.set(t, ch);
                }
            }
        }
        bits
    }

    /// Full inference: audio -> (logits, argmax), through the
    /// lane-blocked incremental-window XNOR-popcount engine
    /// (`model::kernel`) over the decoded bit-planes.
    pub fn infer(&self, audio: &[f32]) -> (Vec<f32>, usize) {
        let mut x = self.preprocess(audio);
        for (li, lane) in self.lanes[..self.lanes.len() - 1].iter().enumerate() {
            let _r = region(layer_name(li));
            x = kernel::conv_layer_lanes(&x, lane);
        }
        let logits = {
            let _r = region("final_gap");
            kernel::final_layer_gap_lanes(&x, self.lanes.last().unwrap())
        };
        let predicted = reference::argmax(&logits);
        (logits, predicted)
    }

    /// The PR 2 packed path (channel-at-a-time plane walk, windows
    /// re-gathered per position): the lane engine's differential oracle
    /// and its benchmark baseline (`benches/backend_throughput.rs` asserts
    /// the engine's speedup over this). Bit-identical to [`Self::infer`].
    pub fn infer_packed_ref(&self, audio: &[f32]) -> (Vec<f32>, usize) {
        let mut x = self.preprocess(audio);
        for packed in &self.layers[..self.layers.len() - 1] {
            x = reference::conv_layer_packed(&x, packed);
        }
        let logits = reference::final_layer_gap_packed(&x, self.layers.last().unwrap());
        let predicted = reference::argmax(&logits);
        (logits, predicted)
    }

    /// Decode/preprocess a whole batch of utterances into packed feature
    /// maps (order preserved).
    pub fn preprocess_batch(&self, batch: &[&[f32]]) -> Vec<BitMap> {
        batch.iter().map(|a| self.preprocess(a)).collect()
    }

    /// Batched inference: every layer's lane blocks are walked **once
    /// per batch** (inner loops over utterances — see
    /// `kernel::conv_layer_lanes_batch`), instead of once per
    /// utterance. Bit-identical to [`Self::infer`] per element for any
    /// batch size (property-tested in `tests/batch_parity.rs`).
    pub fn infer_batch(&self, batch: &[&[f32]]) -> Vec<(Vec<f32>, usize)> {
        if batch.is_empty() {
            return Vec::new();
        }
        let mut xs = self.preprocess_batch(batch);
        for (li, lane) in self.lanes[..self.lanes.len() - 1].iter().enumerate() {
            let _r = region(layer_name(li));
            xs = kernel::conv_layer_lanes_batch(&xs, lane);
        }
        let _r = region("final_gap");
        kernel::final_layer_gap_lanes_batch(&xs, self.lanes.last().unwrap())
            .into_iter()
            .map(|logits| {
                let predicted = reference::argmax(&logits);
                (logits, predicted)
            })
            .collect()
    }

    /// Input row count (`t_in`) of every layer — the number of `cim_conv`
    /// fires each owning macro performs for that layer — walked from the
    /// program's input geometry through the pooling ladder. Feeds the
    /// shard fire accounting below; the variation-aware replay
    /// (`robustness::replay`) derives the same ladder from its evolving
    /// feature map, and this is the reference for what it must match
    /// (one noise draw per SA column per fire).
    pub fn t_ins(&self) -> Vec<usize> {
        let mut t = self.t;
        self.layers
            .iter()
            .map(|l| {
                let t_in = t;
                if l.pooled {
                    t /= 2;
                }
                t_in
            })
            .collect()
    }

    /// Pre-slice the decoded layers for a [`ShardPlan`]: each macro gets
    /// its channel range of every layer's sign planes (a contiguous word
    /// copy). Built once per (program, plan); reused across inferences.
    pub fn shard(&self, plan: &ShardPlan) -> Result<ShardedProgram> {
        plan.validate()?;
        ensure!(
            plan.axis == ShardAxis::Output,
            "channel-slicing shard execution needs an output-axis plan \
             (input-axis plans run through infer_input_sharded)"
        );
        ensure!(
            plan.layers.len() == self.layers.len(),
            "shard plan has {} layers, program has {}",
            plan.layers.len(),
            self.layers.len()
        );
        for (ls, l) in plan.layers.iter().zip(&self.layers) {
            ensure!(
                ls.c_out == l.c_out,
                "layer {}: shard plan c_out {} != decoded {}",
                ls.index,
                ls.c_out,
                l.c_out
            );
        }
        let n = plan.n_macros;
        let mut per_macro: Vec<Vec<Option<(usize, PackedLayer)>>> = vec![Vec::new(); n];
        for (ls, l) in plan.layers.iter().zip(&self.layers) {
            for (m, shards) in per_macro.iter_mut().enumerate() {
                let (a, b) = ls.ranges[m];
                shards.push((b > a).then(|| (a, l.slice_channels(a, b))));
            }
        }
        // Lane-blocked twins of every shard (what the inference paths
        // execute; `per_macro` keeps the replay-stable packed form).
        let lane_per_macro: Vec<Vec<Option<(usize, LaneLayer)>>> = per_macro
            .iter()
            .map(|shards| {
                shards
                    .iter()
                    .map(|s| s.as_ref().map(|(off, p)| (*off, LaneLayer::from_packed(p))))
                    .collect()
            })
            .collect();
        // Fire accounting mirrors the cycle engine's interleave: a macro
        // fires once per row position of every layer it owns channels of.
        let t_ins = self.t_ins();
        let fires_per_macro: Vec<u64> = (0..n)
            .map(|m| {
                per_macro[m]
                    .iter()
                    .zip(&t_ins)
                    .filter(|(s, _)| s.is_some())
                    .map(|(_, &t_in)| t_in as u64)
                    .sum()
            })
            .collect();
        Ok(ShardedProgram { n, per_macro, lane_per_macro, fires_per_macro })
    }

    /// Sharded inference: every layer computed as per-macro channel
    /// shards, concatenated back to the full-width map (bit-identical to
    /// [`Self::infer`]; property-tested in `tests/shard_parity.rs`).
    pub fn infer_sharded(&self, audio: &[f32], sp: &ShardedProgram) -> (Vec<f32>, usize) {
        let n_layers = self.layers.len();
        let mut x = self.preprocess(audio);
        for li in 0..n_layers - 1 {
            let _r = region(layer_name(li));
            let full = &self.layers[li];
            let t_out = if full.pooled { x.t / 2 } else { x.t };
            let mut out = BitMap::zero(t_out, full.c_out);
            for shards in &sp.lane_per_macro {
                if let Some((off, shard)) = &shards[li] {
                    let part = kernel::conv_layer_lanes(&x, shard);
                    let _m = region("merge");
                    reference::merge_shard(&mut out, *off, &part);
                }
            }
            x = out;
        }
        let _r = region("final_gap");
        let mut logits = vec![0.0f32; self.n_classes];
        for shards in &sp.lane_per_macro {
            if let Some((off, shard)) = &shards[n_layers - 1] {
                let part = kernel::final_layer_gap_lanes(&x, shard);
                logits[*off..*off + part.len()].copy_from_slice(&part);
            }
        }
        let predicted = reference::argmax(&logits);
        (logits, predicted)
    }

    /// Batched sharded inference: the batch is carried through every
    /// macro's channel slice — each shard's (smaller) weight planes are
    /// walked once per batch, then the per-utterance partial maps merge
    /// at their global channel offsets. Bit-identical to
    /// [`Self::infer_sharded`] per element.
    pub fn infer_sharded_batch(
        &self,
        batch: &[&[f32]],
        sp: &ShardedProgram,
    ) -> Vec<(Vec<f32>, usize)> {
        if batch.is_empty() {
            return Vec::new();
        }
        let n_layers = self.layers.len();
        let mut xs = self.preprocess_batch(batch);
        for li in 0..n_layers - 1 {
            let _r = region(layer_name(li));
            let full = &self.layers[li];
            let t_out = if full.pooled { xs[0].t / 2 } else { xs[0].t };
            let mut outs: Vec<BitMap> =
                xs.iter().map(|_| BitMap::zero(t_out, full.c_out)).collect();
            for shards in &sp.lane_per_macro {
                if let Some((off, shard)) = &shards[li] {
                    let parts = kernel::conv_layer_lanes_batch(&xs, shard);
                    let _m = region("merge");
                    for (out, part) in outs.iter_mut().zip(&parts) {
                        reference::merge_shard(out, *off, part);
                    }
                }
            }
            xs = outs;
        }
        let _r = region("final_gap");
        let mut logits = vec![vec![0.0f32; self.n_classes]; xs.len()];
        for shards in &sp.lane_per_macro {
            if let Some((off, shard)) = &shards[n_layers - 1] {
                let parts = kernel::final_layer_gap_lanes_batch(&xs, shard);
                for (l, part) in logits.iter_mut().zip(&parts) {
                    l[*off..*off + part.len()].copy_from_slice(part);
                }
            }
        }
        logits
            .into_iter()
            .map(|l| {
                let predicted = reference::argmax(&l);
                (l, predicted)
            })
            .collect()
    }

    /// [`Self::infer_sharded`] with one OS thread per macro: threads
    /// compute their shard of each layer concurrently and rendezvous on a
    /// barrier while one of them concatenates the channel ranges. Same
    /// bits, wall-clock scales with the widest layer's split.
    ///
    /// Panic-safe: a shard thread that panics mid-layer does not poison
    /// the caller — every compute step runs under `catch_unwind`, a
    /// failed thread keeps attending the remaining barrier rendezvous
    /// (abandoning them would deadlock the survivors — the real hazard,
    /// worse than poisoning), and the dead shard surfaces as a typed
    /// `Err` naming the macro and layer. Locks are recovered, never
    /// `unwrap`ed (`util::{lock,read,write}_or_recover`), upholding the
    /// serving stack's poison-recovery contract.
    pub fn infer_sharded_parallel(
        &self,
        audio: &[f32],
        sp: &ShardedProgram,
    ) -> Result<(Vec<f32>, usize)> {
        self.sharded_parallel_impl(audio, sp, None)
    }

    /// The implementation behind [`Self::infer_sharded_parallel`], with a
    /// test-only fault hook: `fault(m, li)` runs at the top of macro `m`'s
    /// layer-`li` compute step and may panic to simulate a dying shard
    /// thread (the poison-regression tests below drive it).
    fn sharded_parallel_impl(
        &self,
        audio: &[f32],
        sp: &ShardedProgram,
        fault: Option<&(dyn Fn(usize, usize) + Sync)>,
    ) -> Result<(Vec<f32>, usize)> {
        let n = sp.n;
        if n <= 1 {
            return Ok(self.infer_sharded(audio, sp));
        }
        let n_layers = self.layers.len();
        let conv_meta: Vec<(bool, usize)> =
            self.layers[..n_layers - 1].iter().map(|l| (l.pooled, l.c_out)).collect();
        let barrier = Barrier::new(n);
        let current = RwLock::new(self.preprocess(audio));
        let partials: Vec<Mutex<Option<(usize, BitMap)>>> =
            (0..n).map(|_| Mutex::new(None)).collect();
        let logit_parts: Mutex<Vec<(usize, Vec<f32>)>> = Mutex::new(Vec::new());
        let dead: Mutex<Vec<String>> = Mutex::new(Vec::new());

        std::thread::scope(|s| {
            for (m, macro_shards) in sp.lane_per_macro.iter().enumerate() {
                let barrier = &barrier;
                let current = &current;
                let partials = &partials;
                let logit_parts = &logit_parts;
                let dead = &dead;
                let conv_meta = &conv_meta;
                s.spawn(move || {
                    let mut failed = false;
                    for (li, &(pooled, c_out)) in conv_meta.iter().enumerate() {
                        if !failed {
                            let step = catch_unwind(AssertUnwindSafe(|| {
                                if let Some(f) = fault {
                                    f(m, li);
                                }
                                let _r = region("shard_compute");
                                let x = read_or_recover(current);
                                let part = macro_shards[li]
                                    .as_ref()
                                    .map(|(off, shard)| (*off, kernel::conv_layer_lanes(&x, shard)));
                                *lock_or_recover(&partials[m]) = part;
                            }));
                            if let Err(p) = step {
                                failed = true;
                                lock_or_recover(dead)
                                    .push(format!("macro {m} layer {li}: {}", panic_msg(&p)));
                                *lock_or_recover(&partials[m]) = None;
                            }
                        }
                        if barrier.wait().is_leader() {
                            // The merge leader is just whichever thread the
                            // barrier elected — it may itself have failed,
                            // so the merge is guarded the same way.
                            let merge = catch_unwind(AssertUnwindSafe(|| {
                                let _r = region("shard_merge");
                                let mut cur = write_or_recover(current);
                                let t_out = if pooled { cur.t / 2 } else { cur.t };
                                let mut out = BitMap::zero(t_out, c_out);
                                for p in partials.iter() {
                                    if let Some((off, bm)) = lock_or_recover(p).take() {
                                        reference::merge_shard(&mut out, off, &bm);
                                    }
                                }
                                *cur = out;
                            }));
                            if let Err(p) = merge {
                                failed = true;
                                lock_or_recover(dead)
                                    .push(format!("merge after layer {li}: {}", panic_msg(&p)));
                            }
                        }
                        barrier.wait(); // merged map visible to everyone
                    }
                    // Past the last barrier: no one waits on this thread
                    // any more, so a failed shard can simply stop.
                    if failed {
                        return;
                    }
                    if let Some((off, shard)) = &macro_shards[n_layers - 1] {
                        let step = catch_unwind(AssertUnwindSafe(|| {
                            if let Some(f) = fault {
                                f(m, n_layers - 1);
                            }
                            let _r = region("final_gap");
                            let x = read_or_recover(current);
                            kernel::final_layer_gap_lanes(&x, shard)
                        }));
                        match step {
                            Ok(part) => lock_or_recover(logit_parts).push((*off, part)),
                            Err(p) => lock_or_recover(dead)
                                .push(format!("macro {m} final layer: {}", panic_msg(&p))),
                        }
                    }
                });
            }
        });

        let dead = dead.into_inner().unwrap_or_else(|p| p.into_inner());
        if !dead.is_empty() {
            bail!(
                "sharded-parallel inference lost {} shard thread(s): {}",
                dead.len(),
                dead.join("; ")
            );
        }
        let mut logits = vec![0.0f32; self.n_classes];
        for (off, part) in logit_parts.into_inner().unwrap_or_else(|p| p.into_inner()) {
            logits[off..off + part.len()].copy_from_slice(&part);
        }
        let predicted = reference::argmax(&logits);
        Ok((logits, predicted))
    }

    /// Input-channel-axis sharded inference ([`ShardAxis::Input`] plans):
    /// every macro computes raw partial sums for **all** output channels
    /// over its input-channel slice; partials merge by integer addition
    /// (the XNOR-popcount sum `2*pop(win & plane) - pop(win)` is additive
    /// over disjoint input masks), then the merged sums run the same
    /// strict-`>` threshold / OR-pool / i64 GAP arithmetic as the
    /// unsharded path — bit-identical logits by construction. The
    /// tensor-level twin of the cycle engine's
    /// `compiler::build_kws_program_input_sharded` schedule, and the
    /// fallback execution form for fused groups whose window exceeds one
    /// macro's wordlines.
    pub fn infer_input_sharded(
        &self,
        audio: &[f32],
        plan: &ShardPlan,
    ) -> Result<(Vec<f32>, usize)> {
        self.validate_input_plan(plan)?;
        let n_layers = self.layers.len();
        let mut x = self.preprocess(audio);
        for (li, l) in self.layers.iter().enumerate() {
            let _r = if li == n_layers - 1 { region("final_gap") } else { region(layer_name(li)) };
            let t_in = x.t;
            let mut window = vec![0u64; l.plane_words];
            let mut sums = vec![0i32; l.c_out];
            // Merged raw sums, one row per position: each macro's masked
            // window sees only its slice's bits, so its sums are exact
            // partials and the adds reconstruct the unsharded values.
            let mut acc = vec![0i32; t_in * l.c_out];
            for (_, c0, c1) in plan.layers[li].non_empty() {
                let part = mask_to_input_slice(&x, c0, c1);
                for t in 0..t_in {
                    reference::conv_sums_packed_into(&part, l, t, &mut window, &mut sums);
                    for (a, &s) in acc[t * l.c_out..(t + 1) * l.c_out].iter_mut().zip(&sums) {
                        *a += s;
                    }
                }
            }
            if li == n_layers - 1 {
                // Raw final layer: GAP over merged sums, f32 division last
                // (same order as `reference::final_layer_gap_packed`).
                let mut gap = vec![0i64; l.c_out];
                for t in 0..t_in {
                    for (g, &s) in gap.iter_mut().zip(&acc[t * l.c_out..(t + 1) * l.c_out]) {
                        *g += s as i64;
                    }
                }
                let logits: Vec<f32> = gap.iter().map(|&g| g as f32 / t_in as f32).collect();
                let predicted = reference::argmax(&logits);
                return Ok((logits, predicted));
            }
            let t_out = if l.pooled { t_in / 2 } else { t_in };
            let mut out = BitMap::zero(t_out, l.c_out);
            for t in 0..t_in {
                let ot = if l.pooled { t / 2 } else { t };
                if ot >= t_out {
                    break; // odd tail dropped by pooling
                }
                let row = &acc[t * l.c_out..(t + 1) * l.c_out];
                for (co, (&s, &th)) in row.iter().zip(&l.thresholds).enumerate() {
                    if s > th {
                        out.set(ot, co); // pooled max == OR of the pair
                    }
                }
            }
            x = out;
        }
        unreachable!("the final layer returns above")
    }

    /// Check an input-axis plan against the decoded geometry (shared by
    /// [`Self::infer_input_sharded`] and `FastSim` configuration, so a
    /// mismatched plan fails at setup, not mid-request).
    pub fn validate_input_plan(&self, plan: &ShardPlan) -> Result<()> {
        plan.validate()?;
        ensure!(
            plan.axis == ShardAxis::Input,
            "input-sharded execution needs an input-axis plan"
        );
        ensure!(
            plan.layers.len() == self.layers.len(),
            "shard plan has {} layers, program has {}",
            plan.layers.len(),
            self.layers.len()
        );
        for (ls, l) in plan.layers.iter().zip(&self.layers) {
            ensure!(
                ls.c_out == l.c_in,
                "layer {}: input plan covers {} channels, layer takes {}",
                ls.index,
                ls.c_out,
                l.c_in
            );
        }
        Ok(())
    }

    /// Fires each macro performs per inference under an input-axis plan:
    /// one per row position of every layer whose input slice is non-empty
    /// for that macro — mirroring the cycle engine's per-position fire
    /// interleave (the input-axis twin of `ShardedProgram::fires_per_macro`).
    pub fn input_fires_per_macro(&self, plan: &ShardPlan) -> Vec<u64> {
        let t_ins = self.t_ins();
        (0..plan.n_macros)
            .map(|m| {
                plan.layers
                    .iter()
                    .zip(&t_ins)
                    .filter(|(ls, _)| !ls.is_empty(m))
                    .map(|(_, &t_in)| t_in as u64)
                    .sum()
            })
            .collect()
    }

    /// Unpack every layer to the scalar tap-major/channel-minor form
    /// (done once; pair with [`Self::infer_scalar`]).
    pub fn to_layer_specs(&self) -> Vec<LayerSpec> {
        self.layers.iter().map(PackedLayer::to_spec).collect()
    }

    /// The PR 1 scalar serving path over pre-unpacked `specs`: per-bit
    /// preprocess + per-channel i8 conv loops. Kept as the oracle and the
    /// throughput baseline for the packed engine.
    pub fn infer_scalar(&self, specs: &[LayerSpec], audio: &[f32]) -> (Vec<f32>, usize) {
        let mut x = self.preprocess_scalar(audio);
        for spec in &specs[..specs.len() - 1] {
            x = reference::conv_layer(&x, spec);
        }
        let logits = reference::final_layer_gap(&x, specs.last().unwrap());
        let predicted = reference::argmax(&logits);
        (logits, predicted)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::OptLevel;
    use crate::compiler::build_kws_program;
    use crate::model::{dataset, KwsModel};

    #[test]
    fn decode_recovers_layer_geometry() {
        let m = KwsModel::synthetic(11);
        let prog = build_kws_program(&m, OptLevel::FULL).unwrap();
        let d = DecodedProgram::decode(&prog).unwrap();
        assert_eq!(d.layers.len(), m.layers.len());
        assert_eq!(d.t, m.t);
        assert_eq!(d.c, m.c);
        assert_eq!(d.n_classes, m.n_classes);
        for (got, want) in d.layers.iter().zip(&m.layers) {
            // The decoded planes ARE the packed form of the source layer:
            // the DRAM stream round-trips without any re-packing.
            assert_eq!(got, &PackedLayer::from_spec(want));
            // And unpacking recovers the scalar weights exactly.
            let spec = got.to_spec();
            assert_eq!(spec.weights, want.weights);
            assert_eq!(spec.thresholds, want.thresholds);
            assert_eq!(spec.c_in, want.c_in);
            assert_eq!(spec.c_out, want.c_out);
            assert_eq!(spec.kernel, want.kernel);
            assert_eq!(spec.pooled, want.pooled);
            assert_eq!(spec.binarized, want.binarized);
        }
    }

    #[test]
    fn decoded_inference_matches_host_reference() {
        let m = KwsModel::synthetic(5);
        let prog = build_kws_program(&m, OptLevel::FULL).unwrap();
        let d = DecodedProgram::decode(&prog).unwrap();
        for seed in 0..3u64 {
            let audio = dataset::synth_utterance(seed as usize % 12, seed, m.audio_len, 0.3);
            let (logits, predicted) = d.infer(&audio);
            let want = crate::model::reference::infer(&m, &audio);
            assert_eq!(logits, want, "seed {seed}");
            assert_eq!(predicted, crate::model::reference::argmax(&want));
        }
    }

    #[test]
    fn vectorized_preprocess_matches_scalar() {
        let m = KwsModel::synthetic(7);
        let prog = build_kws_program(&m, OptLevel::FULL).unwrap();
        let d = DecodedProgram::decode(&prog).unwrap();
        for seed in [0u64, 5, 21] {
            let audio = dataset::synth_utterance(seed as usize % 12, seed, m.audio_len, 0.37);
            assert_eq!(d.preprocess(&audio), d.preprocess_scalar(&audio), "seed {seed}");
        }
    }

    #[test]
    fn packed_inference_matches_scalar_path() {
        let m = KwsModel::synthetic(9);
        let prog = build_kws_program(&m, OptLevel::FULL).unwrap();
        let d = DecodedProgram::decode(&prog).unwrap();
        let specs = d.to_layer_specs();
        for seed in 0..4u64 {
            let audio = dataset::synth_utterance(seed as usize % 12, seed, m.audio_len, 0.37);
            let (packed, pp) = d.infer(&audio);
            let (scalar, sp) = d.infer_scalar(&specs, &audio);
            assert_eq!(packed, scalar, "seed {seed}");
            assert_eq!(pp, sp);
        }
    }

    #[test]
    fn sharded_inference_bit_identical_sequential_and_parallel() {
        use crate::dataflow::shard::ShardPlan;
        let m = KwsModel::synthetic(13);
        let prog = build_kws_program(&m, OptLevel::FULL).unwrap();
        let d = DecodedProgram::decode(&prog).unwrap();
        let audio = dataset::synth_utterance(6, 3, m.audio_len, 0.37);
        let (want, wp) = d.infer(&audio);
        for n in 1..=4 {
            let plan = ShardPlan::even(&prog.plan, n).unwrap();
            let sp = d.shard(&plan).unwrap();
            let (seq, sq) = d.infer_sharded(&audio, &sp);
            assert_eq!(seq, want, "sequential n={n}");
            assert_eq!(sq, wp);
            let (par, pp) = d.infer_sharded_parallel(&audio, &sp).unwrap();
            assert_eq!(par, want, "parallel n={n}");
            assert_eq!(pp, wp);
            // Idle macros fire nothing; owners fire once per position.
            assert_eq!(
                sp.fires_per_macro.iter().sum::<u64>(),
                prog.plan
                    .layers
                    .iter()
                    .map(|lp| {
                        let owners = plan.layers[lp.index].non_empty().len() as u64;
                        owners * lp.t_in as u64
                    })
                    .sum::<u64>()
            );
        }
    }

    #[test]
    fn panicking_shard_thread_yields_error_not_poisoned_caller() {
        // Regression (PR 8): a shard thread dying mid-inference used to
        // poison the shared RwLock/Mutexes and unwind through
        // `thread::scope`, taking the caller down. Now it must surface as
        // a typed Err — no panic, no hang — and the same DecodedProgram
        // must keep serving afterwards.
        let m = KwsModel::synthetic(13);
        let prog = build_kws_program(&m, OptLevel::FULL).unwrap();
        let d = DecodedProgram::decode(&prog).unwrap();
        let audio = dataset::synth_utterance(6, 3, m.audio_len, 0.37);
        let plan = ShardPlan::even(&prog.plan, 3).unwrap();
        let sp = d.shard(&plan).unwrap();
        let n_layers = d.layers.len();

        // A panic at every interesting point in the protocol: first
        // conv layer, a middle layer, and the unbarriered final GAP step.
        for (fm, fl) in [(1usize, 0usize), (2, 1), (0, n_layers - 1)] {
            let fault = move |m: usize, li: usize| {
                if m == fm && li == fl {
                    panic!("chaos: shard {fm} dies at layer {fl}");
                }
            };
            let err = d
                .sharded_parallel_impl(&audio, &sp, Some(&fault))
                .expect_err("a dead shard must surface as Err");
            let msg = format!("{err}");
            assert!(msg.contains("shard thread"), "untyped error: {msg}");
        }

        // The caller (and the shared shard state) survived: a clean run
        // on the same structures still answers bit-identically.
        let (want, wp) = d.infer_sharded(&audio, &sp);
        let (got, gp) = d.infer_sharded_parallel(&audio, &sp).unwrap();
        assert_eq!(got, want, "post-fault inference must be clean");
        assert_eq!(gp, wp);
    }

    #[test]
    fn batched_inference_bit_identical_to_sequential() {
        let m = KwsModel::synthetic(21);
        let prog = build_kws_program(&m, OptLevel::FULL).unwrap();
        let d = DecodedProgram::decode(&prog).unwrap();
        let audios: Vec<Vec<f32>> = (0..5)
            .map(|i| dataset::synth_utterance(i % 12, 40 + i as u64, m.audio_len, 0.37))
            .collect();
        let refs: Vec<&[f32]> = audios.iter().map(|a| a.as_slice()).collect();
        let want: Vec<_> = refs.iter().map(|a| d.infer(a)).collect();
        // Dense batch, including ragged sub-batches and a 1-element batch.
        for take in [1usize, 2, 5] {
            let got = d.infer_batch(&refs[..take]);
            assert_eq!(got, want[..take], "batch size {take}");
        }
        assert!(d.infer_batch(&[]).is_empty());
        // Sharded batch, even and uneven splits.
        for n in 1..=3 {
            let plan = ShardPlan::even(&prog.plan, n).unwrap();
            let sp = d.shard(&plan).unwrap();
            let got = d.infer_sharded_batch(&refs, &sp);
            assert_eq!(got, want, "sharded batch n={n}");
        }
    }

    #[test]
    fn input_sharded_inference_bit_identical() {
        use crate::dataflow::shard::ShardPlan;
        for (name, m) in [
            ("narrow", KwsModel::synthetic(17)),
            ("wide", KwsModel::synthetic_wide(17)),
        ] {
            let prog = build_kws_program(&m, OptLevel::FULL).unwrap();
            let d = DecodedProgram::decode(&prog).unwrap();
            let audio = dataset::synth_utterance(7, 29, m.audio_len, 0.37);
            let (want, wp) = d.infer(&audio);
            for n in 1..=4 {
                let plan = ShardPlan::input_word_aligned(&prog.plan, n).unwrap();
                let (got, gp) = d.infer_input_sharded(&audio, &plan).unwrap();
                assert_eq!(got, want, "{name} n={n}");
                assert_eq!(gp, wp, "{name} n={n}");
                // Every owning macro fires once per position of every
                // layer whose input slice it holds.
                let fires = d.input_fires_per_macro(&plan);
                assert_eq!(fires.len(), n);
                assert_eq!(
                    fires.iter().sum::<u64>(),
                    prog.plan
                        .layers
                        .iter()
                        .map(|lp| plan.layers[lp.index].non_empty().len() as u64
                            * lp.t_in as u64)
                        .sum::<u64>(),
                    "{name} n={n}"
                );
            }
        }
    }

    #[test]
    fn shard_rejects_input_axis_plan() {
        use crate::dataflow::shard::ShardPlan;
        let prog = build_kws_program(&KwsModel::synthetic(3), OptLevel::FULL).unwrap();
        let d = DecodedProgram::decode(&prog).unwrap();
        let plan = ShardPlan::input_word_aligned(&prog.plan, 2).unwrap();
        assert!(d.shard(&plan).is_err(), "output-axis slicer must reject input plans");
        // And the input path rejects output-axis plans symmetrically.
        let out_plan = ShardPlan::even(&prog.plan, 2).unwrap();
        let audio = dataset::synth_utterance(1, 1, prog.plan.audio_bytes as usize / 2, 0.3);
        assert!(d.infer_input_sharded(&audio, &out_plan).is_err());
    }

    #[test]
    fn shard_rejects_mismatched_plan() {
        use crate::dataflow::shard::ShardPlan;
        let a = build_kws_program(&KwsModel::synthetic(1), OptLevel::FULL).unwrap();
        let b = build_kws_program(&KwsModel::synthetic_wide(1), OptLevel::FULL).unwrap();
        let d = DecodedProgram::decode(&a).unwrap();
        let plan_b = ShardPlan::even(&b.plan, 2).unwrap();
        assert!(d.shard(&plan_b).is_err());
    }

    #[test]
    fn opt_level_never_changes_decoded_values() {
        let m = KwsModel::synthetic(2);
        let audio = dataset::synth_utterance(4, 9, m.audio_len, 0.3);
        let mut logits: Option<Vec<f32>> = None;
        for (name, opt) in OptLevel::ladder() {
            let prog = build_kws_program(&m, opt).unwrap();
            let (l, _) = DecodedProgram::decode(&prog).unwrap().infer(&audio);
            if let Some(prev) = &logits {
                assert_eq!(&l, prev, "{name} changed logits");
            }
            logits = Some(l);
        }
    }
}
