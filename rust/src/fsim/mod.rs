//! The fast functional simulator: tensor-level execution of compiled
//! program images with analytical latency/energy accounting.
//!
//! The cycle-level [`crate::sim::Soc`] is the ground truth but costs
//! ~10^6 simulated steps per inference — far too slow to serve traffic.
//! [`FastSim`] executes the same [`Program`] in three parts:
//!
//! * [`exec`]    — decodes the image's weight streams + DMEM tables
//!   (straight into packed sign bit-planes: the stream layout *is* the
//!   [`model::reference::PackedLayer`](crate::model::reference::PackedLayer)
//!   layout) and runs the XNOR-popcount kernels over them: logits
//!   bit-identical to the SoC.
//! * [`latency`] — an analytical cycle/phase model that mirrors the code
//!   generator's emission structure (calibrated against
//!   `sim::stats::PhaseBreakdown`; parity-tested to ≤ 5% error).
//! * energy      — `energy::EnergyTable` applied to the walk's activity
//!   counts (`EnergyReport::from_counts`).
//!
//! Inference latency and energy are data-independent (every branch the
//! compiler emits is a loop counter), so a [`Calibration`] captured from
//! one cycle-accurate run can optionally replace the analytical numbers
//! with exact ones — that is what `backend::FastBackend` exposes.

pub mod exec;
pub mod latency;

pub use exec::{DecodedProgram, ShardedProgram};
pub use latency::Estimate;

use anyhow::Result;

use crate::compiler::Program;
use crate::dataflow::shard::{ShardAxis, ShardPlan};
use crate::energy::{EnergyReport, EnergyTable};
use crate::mem::dram::DramConfig;
use crate::robustness::VariationParams;
use crate::sim::{PhaseBreakdown, RunResult};

/// Exact timing/energy numbers captured from one cycle-level run of the
/// same program (valid for every input: latency is data-independent).
#[derive(Debug, Clone)]
pub struct Calibration {
    pub cycles: u64,
    pub instret: u64,
    pub phases: PhaseBreakdown,
    pub energy: EnergyReport,
    /// The cycle run's MMIO phase-marker stream (exact engine timeline
    /// for the telemetry trace exporter).
    pub markers: Vec<(u32, u64)>,
}

impl Calibration {
    pub fn from_run(r: &RunResult) -> Self {
        Calibration {
            cycles: r.cycles,
            instret: r.instret,
            phases: r.phases,
            energy: r.energy.clone(),
            markers: r.markers.clone(),
        }
    }
}

/// Sharded execution state: the pre-sliced per-macro layers plus the
/// threading choice.
#[derive(Debug, Clone)]
struct ShardedExec {
    prog: ShardedProgram,
    /// One OS thread per macro per inference (see
    /// `DecodedProgram::infer_sharded_parallel`). Off by default in the
    /// coordinator, whose workers already parallelize across requests.
    parallel: bool,
}

/// The fast functional simulator for one compiled program.
#[derive(Debug, Clone)]
pub struct FastSim {
    program: Program,
    decoded: DecodedProgram,
    estimate: Estimate,
    energy_table: EnergyTable,
    calibration: Option<Calibration>,
    sharded: Option<ShardedExec>,
    /// Input-channel-axis plan ([`ShardAxis::Input`]): inference routes
    /// through `DecodedProgram::infer_input_sharded` (per-macro raw
    /// partial sums, merged by addition). Mutually exclusive with
    /// `sharded` — an image is split along one axis at a time.
    input_plan: Option<ShardPlan>,
    /// Thread cap for [`Self::infer_batch`]'s chunked fan-out: `None` =
    /// one thread per available core, `Some(1)` = stay on the caller's
    /// thread (what the coordinator uses when its workers already
    /// parallelize across requests).
    batch_threads: Option<usize>,
    /// Serve disturbed inferences: every `infer`/`infer_batch` replays
    /// the cycle engine's per-fire variation at tensor level
    /// (`robustness::replay`), fresh per-macro streams per inference.
    variation: Option<VariationParams>,
}

impl FastSim {
    /// Build from a compiled image (decodes weights, runs the analytical
    /// latency walk once — both are reused across all inferences). A
    /// sharded image (`build_kws_program_sharded` with `n_macros > 1`)
    /// automatically executes through per-macro shard groups.
    pub fn new(program: Program, dram_cfg: DramConfig) -> Result<Self> {
        let decoded = DecodedProgram::decode(&program)?;
        let estimate = latency::estimate(&program, &dram_cfg);
        let (sharded, input_plan) = if program.shards.n_macros > 1 {
            match program.shards.axis {
                ShardAxis::Output => (
                    Some(ShardedExec { prog: decoded.shard(&program.shards)?, parallel: false }),
                    None,
                ),
                ShardAxis::Input => {
                    decoded.validate_input_plan(&program.shards)?;
                    (None, Some(program.shards.clone()))
                }
            }
        } else {
            (None, None)
        };
        Ok(FastSim {
            program,
            decoded,
            estimate,
            energy_table: EnergyTable::default(),
            calibration: None,
            sharded,
            input_plan,
            batch_threads: None,
            variation: None,
        })
    }

    /// Execute through an explicit [`ShardPlan`] (any channel-granular
    /// split — the cycle engine is limited to word-aligned plans, the
    /// functional simulator is not). `parallel` runs one thread per macro
    /// per inference.
    pub fn with_shard_plan(mut self, plan: &ShardPlan, parallel: bool) -> Result<Self> {
        if plan.axis == ShardAxis::Input {
            self.decoded.validate_input_plan(plan)?;
            self.input_plan = (plan.n_macros > 1).then(|| plan.clone());
            self.sharded = None;
            return Ok(self);
        }
        self.input_plan = None;
        self.sharded = if plan.n_macros > 1 || parallel {
            Some(ShardedExec { prog: self.decoded.shard(plan)?, parallel })
        } else {
            None
        };
        Ok(self)
    }

    /// Per-macro fire counts of one inference (a single entry when the
    /// program is unsharded).
    pub fn shard_fires(&self) -> Vec<u64> {
        match (&self.sharded, &self.input_plan) {
            (Some(se), _) => se.prog.fires_per_macro.clone(),
            (None, Some(plan)) => self.decoded.input_fires_per_macro(plan),
            (None, None) => vec![self.estimate.counts.fires],
        }
    }

    pub fn with_energy_table(mut self, t: EnergyTable) -> Self {
        self.energy_table = t;
        self
    }

    /// Snap latency/energy to numbers measured on the cycle simulator.
    pub fn with_calibration(mut self, c: Calibration) -> Self {
        self.calibration = Some(c);
        self
    }

    /// Cap [`Self::infer_batch`]'s thread fan-out (`1` keeps the whole
    /// batch on the caller's thread; the default is one per core).
    pub fn with_batch_threads(mut self, n: usize) -> Self {
        self.batch_threads = Some(n.max(1));
        self
    }

    /// Serve *disturbed* inferences: every request replays the macro
    /// bank's `VariationModel` fire sequence at tensor level with fresh
    /// per-macro streams seeded from `v.seed` (`serve --variation` /
    /// fault-injection scenarios). Timing/energy accounting is untouched
    /// — the compiled program's latency is data-independent and the
    /// disturbance is analog, not temporal.
    pub fn with_variation(mut self, v: VariationParams) -> Self {
        self.variation = Some(v);
        self
    }

    pub fn variation(&self) -> Option<&VariationParams> {
        self.variation.as_ref()
    }

    pub fn program(&self) -> &Program {
        &self.program
    }

    pub fn decoded(&self) -> &DecodedProgram {
        &self.decoded
    }

    pub fn estimate(&self) -> &Estimate {
        &self.estimate
    }

    pub fn is_calibrated(&self) -> bool {
        self.calibration.is_some()
    }

    /// One inference. Logits are bit-identical to `Soc::infer` on the
    /// same program; cycles/energy come from the analytical model (or the
    /// calibration when present). Note `&self`: the functional simulator
    /// is stateless across requests and safe to share behind an `Arc`.
    pub fn infer(&self, audio: &[f32]) -> RunResult {
        if let Some(v) = &self.variation {
            return self.infer_disturbed(audio, v);
        }
        let out = match &self.sharded {
            // Availability over parallelism: if a shard thread died
            // (typed Err from the panic-safe protocol), degrade to the
            // bit-identical sequential walk instead of failing the
            // request — the PR 7 contract is that faults shed load or
            // degrade, never wedge or poison.
            Some(se) if se.parallel => self
                .decoded
                .infer_sharded_parallel(audio, &se.prog)
                .unwrap_or_else(|_| self.decoded.infer_sharded(audio, &se.prog)),
            Some(se) => self.decoded.infer_sharded(audio, &se.prog),
            // Input-axis split: per-macro raw partials merged by addition
            // (the plan was validated at setup, so this cannot fail; the
            // unsharded walk is the bit-identical safety net regardless).
            None => match &self.input_plan {
                Some(plan) => self
                    .decoded
                    .infer_input_sharded(audio, plan)
                    .unwrap_or_else(|_| self.decoded.infer(audio)),
                None => self.decoded.infer(audio),
            },
        };
        self.finish(out)
    }

    /// One *disturbed* inference with explicit parameters (overriding any
    /// [`Self::with_variation`] default) — the Monte-Carlo sweep hot
    /// path. Honors the active shard layout: an output-sharded program
    /// replays one independent noise stream per macro, exactly like the
    /// SoC's macro bank. Input-axis plans replay as one logical macro
    /// (the replay's fire walk is defined along the output axis; the
    /// clean input-sharded path is bit-identical to unsharded anyway).
    pub fn infer_disturbed(&self, audio: &[f32], params: &VariationParams) -> RunResult {
        let sp = self.sharded.as_ref().map(|se| &se.prog);
        self.finish(crate::robustness::infer_disturbed(&self.decoded, sp, params, audio))
    }

    /// A batch of disturbed inferences: per-utterance fresh streams (each
    /// element is an independent Monte-Carlo trial), so batching can
    /// never change a result — parity with sequential
    /// [`Self::infer_disturbed`] is structural.
    pub fn infer_batch_disturbed(
        &self,
        batch: &[&[f32]],
        params: &VariationParams,
    ) -> Vec<RunResult> {
        batch.iter().map(|a| self.infer_disturbed(a, params)).collect()
    }

    /// A batch of inferences in one call: each layer's weight planes are
    /// walked once per batch (`DecodedProgram::infer_batch`) — the
    /// serving-side realization of the macro's weight-stationary
    /// dataflow — and large batches additionally fan out across up to
    /// [`Self::with_batch_threads`] OS threads in contiguous chunks
    /// (the simulator is `&self`-stateless, so chunks are independent).
    /// Per-element results are bit-identical to [`Self::infer`];
    /// chip-side cycles/energy are per-inference numbers, unchanged by
    /// batching (the chip still runs utterances back to back — batching
    /// amortizes *host* cost).
    pub fn infer_batch(&self, batch: &[&[f32]]) -> Vec<RunResult> {
        if batch.is_empty() {
            return Vec::new();
        }
        if batch.len() == 1 {
            return vec![self.infer(batch[0])];
        }
        let workers = self
            .batch_threads
            .unwrap_or_else(|| {
                std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1)
            })
            .clamp(1, batch.len());
        let outs: Vec<(Vec<f32>, usize)> = if workers <= 1 {
            self.infer_batch_chunk(batch)
        } else {
            let chunk = batch.len().div_ceil(workers);
            std::thread::scope(|s| {
                let handles: Vec<_> = batch
                    .chunks(chunk)
                    .map(|c| (c, s.spawn(move || self.infer_batch_chunk(c))))
                    .collect();
                // Joining a panicked scoped thread consumes the panic
                // (the scope would otherwise re-raise it at exit and take
                // the whole batch down); recompute that chunk here on the
                // caller's thread — a transient fault costs latency, a
                // deterministic one reproduces where it's debuggable.
                handles
                    .into_iter()
                    .flat_map(|(c, h)| h.join().unwrap_or_else(|_| self.infer_batch_chunk(c)))
                    .collect()
            })
        };
        outs.into_iter().map(|out| self.finish(out)).collect()
    }

    /// One contiguous chunk of a batch on the current thread, through the
    /// batched (optionally sharded) kernels — or the per-utterance
    /// disturbed replay when a variation model is configured (each
    /// element draws its own fresh noise streams, so there is no
    /// cross-utterance weight-walk to amortize).
    fn infer_batch_chunk(&self, batch: &[&[f32]]) -> Vec<(Vec<f32>, usize)> {
        if let Some(v) = &self.variation {
            let sp = self.sharded.as_ref().map(|se| &se.prog);
            return batch
                .iter()
                .map(|a| crate::robustness::infer_disturbed(&self.decoded, sp, v, a))
                .collect();
        }
        match (&self.sharded, &self.input_plan) {
            (Some(se), _) => self.decoded.infer_sharded_batch(batch, &se.prog),
            (None, Some(plan)) => batch
                .iter()
                .map(|a| {
                    self.decoded
                        .infer_input_sharded(a, plan)
                        .unwrap_or_else(|_| self.decoded.infer(a))
                })
                .collect(),
            (None, None) => self.decoded.infer_batch(batch),
        }
    }

    /// Wrap raw (logits, argmax) in the full accounting record.
    fn finish(&self, (logits, predicted): (Vec<f32>, usize)) -> RunResult {
        let (cycles, instret, phases, energy, markers) = match &self.calibration {
            Some(c) => (c.cycles, c.instret, c.phases, c.energy.clone(), c.markers.clone()),
            None => (
                self.estimate.cycles,
                self.estimate.instret,
                self.estimate.phases,
                EnergyReport::from_counts(&self.energy_table, &self.estimate.counts),
                self.estimate.markers.clone(),
            ),
        };
        RunResult {
            logits,
            predicted,
            cycles,
            instret,
            phases,
            energy,
            seconds_at_50mhz: crate::clock::cycles_to_seconds(cycles),
            console: String::new(),
            shard_fires: self.shard_fires(),
            markers,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::OptLevel;
    use crate::compiler::build_kws_program;
    use crate::model::{dataset, KwsModel};

    #[test]
    fn fastsim_runs_and_reports() {
        let m = KwsModel::synthetic(3);
        let prog = build_kws_program(&m, OptLevel::FULL).unwrap();
        let sim = FastSim::new(prog, DramConfig::default()).unwrap();
        let audio = dataset::synth_utterance(2, 5, m.audio_len, 0.3);
        let r = sim.infer(&audio);
        assert_eq!(r.logits.len(), m.n_classes);
        assert!(r.cycles > 0 && r.instret > 0);
        assert_eq!(r.phases.total(), r.cycles);
        assert!(r.energy.total_pj > 0.0 && r.energy.macro_pj > 0.0);
        assert!(r.seconds_at_50mhz > 0.0);
        // Stateless: repeated inference is identical.
        let r2 = sim.infer(&audio);
        assert_eq!(r.logits, r2.logits);
        assert_eq!(r.cycles, r2.cycles);
    }

    #[test]
    fn sharded_fastsim_matches_unsharded_bits() {
        let m = KwsModel::synthetic(8);
        let single = FastSim::new(
            crate::compiler::build_kws_program(&m, OptLevel::FULL).unwrap(),
            DramConfig::default(),
        )
        .unwrap();
        let audio = dataset::synth_utterance(1, 4, m.audio_len, 0.3);
        let want = single.infer(&audio);
        assert_eq!(want.shard_fires.len(), 1);

        // Auto-sharded from program metadata...
        let prog = crate::compiler::build_kws_program_sharded(&m, OptLevel::FULL, 2).unwrap();
        let sharded = FastSim::new(prog, DramConfig::default()).unwrap();
        let got = sharded.infer(&audio);
        assert_eq!(got.logits, want.logits);
        assert_eq!(got.shard_fires.len(), 2);
        // ...and through an explicit uneven plan with threads.
        let prog = crate::compiler::build_kws_program(&m, OptLevel::FULL).unwrap();
        let plan = crate::dataflow::shard::ShardPlan::even(&prog.plan, 3).unwrap();
        let threaded = FastSim::new(prog, DramConfig::default())
            .unwrap()
            .with_shard_plan(&plan, true)
            .unwrap();
        let got = threaded.infer(&audio);
        assert_eq!(got.logits, want.logits);
        assert_eq!(got.shard_fires.len(), 3);
    }

    #[test]
    fn input_sharded_fastsim_matches_unsharded_bits() {
        let m = KwsModel::synthetic(19);
        let single = FastSim::new(
            build_kws_program(&m, OptLevel::FULL).unwrap(),
            DramConfig::default(),
        )
        .unwrap();
        let audio = dataset::synth_utterance(3, 8, m.audio_len, 0.3);
        let want = single.infer(&audio);

        // Auto-routed from an input-sharded image's metadata...
        let prog =
            crate::compiler::build_kws_program_input_sharded(&m, OptLevel::FULL, 2).unwrap();
        let sim = FastSim::new(prog, DramConfig::default()).unwrap();
        let got = sim.infer(&audio);
        assert_eq!(got.logits, want.logits);
        assert_eq!(got.predicted, want.predicted);
        assert_eq!(got.shard_fires.len(), 2);
        assert!(got.shard_fires.iter().all(|&f| f > 0), "{:?}", got.shard_fires);

        // ...and through an explicit input plan on an unsharded image,
        // including the batched route.
        let prog = build_kws_program(&m, OptLevel::FULL).unwrap();
        let plan =
            crate::dataflow::shard::ShardPlan::input_word_aligned(&prog.plan, 2).unwrap();
        let sim = FastSim::new(prog, DramConfig::default())
            .unwrap()
            .with_shard_plan(&plan, false)
            .unwrap();
        for r in sim.infer_batch(&[&audio, &audio]) {
            assert_eq!(r.logits, want.logits);
        }
    }

    #[test]
    fn infer_batch_matches_sequential_threaded_and_not() {
        let m = KwsModel::synthetic(14);
        let prog = build_kws_program(&m, OptLevel::FULL).unwrap();
        let audios: Vec<Vec<f32>> = (0..7)
            .map(|i| dataset::synth_utterance(i % 12, 60 + i as u64, m.audio_len, 0.37))
            .collect();
        let refs: Vec<&[f32]> = audios.iter().map(|a| a.as_slice()).collect();
        for threads in [1usize, 3] {
            for macros in [1usize, 2] {
                let prog = crate::compiler::build_kws_program_sharded(&m, OptLevel::FULL, macros)
                    .unwrap();
                let sim = FastSim::new(prog, DramConfig::default())
                    .unwrap()
                    .with_batch_threads(threads);
                let want: Vec<RunResult> = refs.iter().map(|a| sim.infer(a)).collect();
                let got = sim.infer_batch(&refs);
                assert_eq!(got.len(), want.len());
                for (g, w) in got.iter().zip(&want) {
                    assert_eq!(g.logits, w.logits, "threads {threads} macros {macros}");
                    assert_eq!(g.predicted, w.predicted);
                    assert_eq!(g.cycles, w.cycles);
                    assert_eq!(g.shard_fires, w.shard_fires);
                }
            }
        }
        let sim = FastSim::new(prog, DramConfig::default()).unwrap();
        assert!(sim.infer_batch(&[]).is_empty());
    }

    #[test]
    fn variation_routing_and_batch_trial_independence() {
        use crate::robustness::VariationParams;
        let m = KwsModel::synthetic(4);
        let prog = build_kws_program(&m, OptLevel::FULL).unwrap();
        let sim = FastSim::new(prog.clone(), DramConfig::default()).unwrap();
        let audios: Vec<Vec<f32>> = (0..3)
            .map(|i| dataset::synth_utterance(i % 12, 70 + i as u64, m.audio_len, 0.3))
            .collect();
        let refs: Vec<&[f32]> = audios.iter().map(|a| a.as_slice()).collect();

        // A no-op model routes through the replay but changes nothing.
        let noop = VariationParams::default();
        let clean = sim.infer(refs[0]);
        let via_replay = sim.infer_disturbed(refs[0], &noop);
        assert_eq!(via_replay.logits, clean.logits);
        assert_eq!(via_replay.cycles, clean.cycles, "timing is untouched by variation");

        // with_variation makes infer/infer_batch serve disturbed bits;
        // every batch element is an independent trial (same seed => same
        // disturbance per utterance, regardless of batch grouping).
        let p = VariationParams { sigma: 0.5, nl_alpha: 0.3, symmetric: false, ..noop };
        let vsim = FastSim::new(prog, DramConfig::default())
            .unwrap()
            .with_variation(p)
            .with_batch_threads(2);
        let seq: Vec<RunResult> = refs.iter().map(|a| vsim.infer(a)).collect();
        assert_ne!(seq[0].logits, clean.logits, "sigma 0.5 single-ended must disturb");
        let batched = vsim.infer_batch(&refs);
        for (b, s) in batched.iter().zip(&seq) {
            assert_eq!(b.logits, s.logits);
            assert_eq!(b.predicted, s.predicted);
        }
        let explicit = vsim.infer_batch_disturbed(&refs, &p);
        for (e, s) in explicit.iter().zip(&seq) {
            assert_eq!(e.logits, s.logits);
        }
    }

    #[test]
    fn calibration_overrides_analytical_numbers() {
        let m = KwsModel::synthetic(6);
        let prog = build_kws_program(&m, OptLevel::FULL).unwrap();
        let sim = FastSim::new(prog, DramConfig::default()).unwrap();
        let audio = dataset::synth_utterance(0, 1, m.audio_len, 0.3);
        let base = sim.infer(&audio);
        let cal = Calibration {
            cycles: 123_456,
            instret: 99,
            phases: PhaseBreakdown::default(),
            energy: EnergyReport::default(),
            markers: vec![(1, 100)],
        };
        let sim = sim.with_calibration(cal);
        assert!(sim.is_calibrated());
        let r = sim.infer(&audio);
        assert_eq!(r.cycles, 123_456);
        assert_eq!(r.instret, 99);
        // The calibrated marker stream rides along for trace export.
        assert_eq!(r.markers, vec![(1, 100)]);
        // Logits are untouched by calibration.
        assert_eq!(r.logits, base.logits);
    }
}
