//! The fast functional simulator: tensor-level execution of compiled
//! program images with analytical latency/energy accounting.
//!
//! The cycle-level [`crate::sim::Soc`] is the ground truth but costs
//! ~10^6 simulated steps per inference — far too slow to serve traffic.
//! [`FastSim`] executes the same [`Program`] in three parts:
//!
//! * [`exec`]    — decodes the image's weight streams + DMEM tables
//!   (straight into packed sign bit-planes: the stream layout *is* the
//!   [`model::reference::PackedLayer`](crate::model::reference::PackedLayer)
//!   layout) and runs the XNOR-popcount kernels over them: logits
//!   bit-identical to the SoC.
//! * [`latency`] — an analytical cycle/phase model that mirrors the code
//!   generator's emission structure (calibrated against
//!   `sim::stats::PhaseBreakdown`; parity-tested to ≤ 5% error).
//! * energy      — `energy::EnergyTable` applied to the walk's activity
//!   counts (`EnergyReport::from_counts`).
//!
//! Inference latency and energy are data-independent (every branch the
//! compiler emits is a loop counter), so a [`Calibration`] captured from
//! one cycle-accurate run can optionally replace the analytical numbers
//! with exact ones — that is what `backend::FastBackend` exposes.

pub mod exec;
pub mod latency;

pub use exec::{DecodedProgram, ShardedProgram};
pub use latency::Estimate;

use anyhow::Result;

use crate::compiler::Program;
use crate::dataflow::shard::ShardPlan;
use crate::energy::{EnergyReport, EnergyTable};
use crate::mem::dram::DramConfig;
use crate::sim::{PhaseBreakdown, RunResult};

/// Exact timing/energy numbers captured from one cycle-level run of the
/// same program (valid for every input: latency is data-independent).
#[derive(Debug, Clone)]
pub struct Calibration {
    pub cycles: u64,
    pub instret: u64,
    pub phases: PhaseBreakdown,
    pub energy: EnergyReport,
}

impl Calibration {
    pub fn from_run(r: &RunResult) -> Self {
        Calibration {
            cycles: r.cycles,
            instret: r.instret,
            phases: r.phases,
            energy: r.energy.clone(),
        }
    }
}

/// Sharded execution state: the pre-sliced per-macro layers plus the
/// threading choice.
#[derive(Debug, Clone)]
struct ShardedExec {
    prog: ShardedProgram,
    /// One OS thread per macro per inference (see
    /// `DecodedProgram::infer_sharded_parallel`). Off by default in the
    /// coordinator, whose workers already parallelize across requests.
    parallel: bool,
}

/// The fast functional simulator for one compiled program.
#[derive(Debug, Clone)]
pub struct FastSim {
    program: Program,
    decoded: DecodedProgram,
    estimate: Estimate,
    energy_table: EnergyTable,
    calibration: Option<Calibration>,
    sharded: Option<ShardedExec>,
}

impl FastSim {
    /// Build from a compiled image (decodes weights, runs the analytical
    /// latency walk once — both are reused across all inferences). A
    /// sharded image (`build_kws_program_sharded` with `n_macros > 1`)
    /// automatically executes through per-macro shard groups.
    pub fn new(program: Program, dram_cfg: DramConfig) -> Result<Self> {
        let decoded = DecodedProgram::decode(&program)?;
        let estimate = latency::estimate(&program, &dram_cfg);
        let sharded = if program.shards.n_macros > 1 {
            Some(ShardedExec { prog: decoded.shard(&program.shards)?, parallel: false })
        } else {
            None
        };
        Ok(FastSim {
            program,
            decoded,
            estimate,
            energy_table: EnergyTable::default(),
            calibration: None,
            sharded,
        })
    }

    /// Execute through an explicit [`ShardPlan`] (any channel-granular
    /// split — the cycle engine is limited to word-aligned plans, the
    /// functional simulator is not). `parallel` runs one thread per macro
    /// per inference.
    pub fn with_shard_plan(mut self, plan: &ShardPlan, parallel: bool) -> Result<Self> {
        self.sharded = if plan.n_macros > 1 || parallel {
            Some(ShardedExec { prog: self.decoded.shard(plan)?, parallel })
        } else {
            None
        };
        Ok(self)
    }

    /// Per-macro fire counts of one inference (a single entry when the
    /// program is unsharded).
    pub fn shard_fires(&self) -> Vec<u64> {
        match &self.sharded {
            Some(se) => se.prog.fires_per_macro.clone(),
            None => vec![self.estimate.counts.fires],
        }
    }

    pub fn with_energy_table(mut self, t: EnergyTable) -> Self {
        self.energy_table = t;
        self
    }

    /// Snap latency/energy to numbers measured on the cycle simulator.
    pub fn with_calibration(mut self, c: Calibration) -> Self {
        self.calibration = Some(c);
        self
    }

    pub fn program(&self) -> &Program {
        &self.program
    }

    pub fn decoded(&self) -> &DecodedProgram {
        &self.decoded
    }

    pub fn estimate(&self) -> &Estimate {
        &self.estimate
    }

    pub fn is_calibrated(&self) -> bool {
        self.calibration.is_some()
    }

    /// One inference. Logits are bit-identical to `Soc::infer` on the
    /// same program; cycles/energy come from the analytical model (or the
    /// calibration when present). Note `&self`: the functional simulator
    /// is stateless across requests and safe to share behind an `Arc`.
    pub fn infer(&self, audio: &[f32]) -> RunResult {
        let (logits, predicted) = match &self.sharded {
            Some(se) if se.parallel => self.decoded.infer_sharded_parallel(audio, &se.prog),
            Some(se) => self.decoded.infer_sharded(audio, &se.prog),
            None => self.decoded.infer(audio),
        };
        let (cycles, instret, phases, energy) = match &self.calibration {
            Some(c) => (c.cycles, c.instret, c.phases, c.energy.clone()),
            None => (
                self.estimate.cycles,
                self.estimate.instret,
                self.estimate.phases,
                EnergyReport::from_counts(&self.energy_table, &self.estimate.counts),
            ),
        };
        RunResult {
            logits,
            predicted,
            cycles,
            instret,
            phases,
            energy,
            seconds_at_50mhz: cycles as f64 / 50e6,
            console: String::new(),
            shard_fires: self.shard_fires(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::OptLevel;
    use crate::compiler::build_kws_program;
    use crate::model::{dataset, KwsModel};

    #[test]
    fn fastsim_runs_and_reports() {
        let m = KwsModel::synthetic(3);
        let prog = build_kws_program(&m, OptLevel::FULL).unwrap();
        let sim = FastSim::new(prog, DramConfig::default()).unwrap();
        let audio = dataset::synth_utterance(2, 5, m.audio_len, 0.3);
        let r = sim.infer(&audio);
        assert_eq!(r.logits.len(), m.n_classes);
        assert!(r.cycles > 0 && r.instret > 0);
        assert_eq!(r.phases.total(), r.cycles);
        assert!(r.energy.total_pj > 0.0 && r.energy.macro_pj > 0.0);
        assert!(r.seconds_at_50mhz > 0.0);
        // Stateless: repeated inference is identical.
        let r2 = sim.infer(&audio);
        assert_eq!(r.logits, r2.logits);
        assert_eq!(r.cycles, r2.cycles);
    }

    #[test]
    fn sharded_fastsim_matches_unsharded_bits() {
        let m = KwsModel::synthetic(8);
        let single = FastSim::new(
            crate::compiler::build_kws_program(&m, OptLevel::FULL).unwrap(),
            DramConfig::default(),
        )
        .unwrap();
        let audio = dataset::synth_utterance(1, 4, m.audio_len, 0.3);
        let want = single.infer(&audio);
        assert_eq!(want.shard_fires.len(), 1);

        // Auto-sharded from program metadata...
        let prog = crate::compiler::build_kws_program_sharded(&m, OptLevel::FULL, 2).unwrap();
        let sharded = FastSim::new(prog, DramConfig::default()).unwrap();
        let got = sharded.infer(&audio);
        assert_eq!(got.logits, want.logits);
        assert_eq!(got.shard_fires.len(), 2);
        // ...and through an explicit uneven plan with threads.
        let prog = crate::compiler::build_kws_program(&m, OptLevel::FULL).unwrap();
        let plan = crate::dataflow::shard::ShardPlan::even(&prog.plan, 3).unwrap();
        let threaded = FastSim::new(prog, DramConfig::default())
            .unwrap()
            .with_shard_plan(&plan, true)
            .unwrap();
        let got = threaded.infer(&audio);
        assert_eq!(got.logits, want.logits);
        assert_eq!(got.shard_fires.len(), 3);
    }

    #[test]
    fn calibration_overrides_analytical_numbers() {
        let m = KwsModel::synthetic(6);
        let prog = build_kws_program(&m, OptLevel::FULL).unwrap();
        let sim = FastSim::new(prog, DramConfig::default()).unwrap();
        let audio = dataset::synth_utterance(0, 1, m.audio_len, 0.3);
        let base = sim.infer(&audio);
        let cal = Calibration {
            cycles: 123_456,
            instret: 99,
            phases: PhaseBreakdown::default(),
            energy: EnergyReport::default(),
        };
        let sim = sim.with_calibration(cal);
        assert!(sim.is_calibrated());
        let r = sim.infer(&audio);
        assert_eq!(r.cycles, 123_456);
        assert_eq!(r.instret, 99);
        // Logits are untouched by calibration.
        assert_eq!(r.logits, base.logits);
    }
}
