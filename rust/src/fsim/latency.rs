//! Analytical latency + activity model for compiled KWS programs.
//!
//! Walks the *same emission structure* as `compiler::codegen` — boot,
//! preprocessing, per-layer weight bursts and row-wise convolution — but
//! instead of emitting instructions it accumulates their documented cycle
//! costs (`cpu` module timing model: ALU/CIM 1, loads 2, stores 1, taken
//! branches 2, see `cpu/mod.rs`) plus a uDMA/DRAM timeline built on the
//! real `mem::dram` timing primitive. The result is a cycle estimate with
//! the same phase markers the cycle simulator records, calibrated against
//! `sim::stats::PhaseBreakdown` (the parity suite bounds the error at
//! ≤ 5%; the remaining slack is descriptor-chain launch quantization —
//! the real uDMA launches chained transfers on the next CPU tick, the
//! model launches them at the exact completion cycle).
//!
//! Because the per-device event counts fall out of the same walk, the
//! model also produces an [`ActivityCounts`] for `energy::EnergyTable`
//! accounting — `fsim` fills `RunResult::energy` from it.

use std::collections::VecDeque;

use crate::baselines::OptLevel;
use crate::cim::mode::{CimConfig, Mode};
use crate::cim::weight_map;
use crate::compiler::{FusionPlan, Program};
use crate::dataflow::plan::{self, KwsPlan};
use crate::dataflow::shard::{ShardAxis, ShardPlan};
use crate::energy::ActivityCounts;
use crate::mem::dram::{Dram, DramConfig};
use crate::mem::layout;
use crate::sim::PhaseBreakdown;

const FM: i64 = layout::FM_BASE as i64;
const DMEM: i64 = layout::DMEM_BASE as i64;
const WT: i64 = layout::WT_BASE as i64;
const DRAM: i64 = layout::DRAM_BASE as i64;
const MMIO: i64 = layout::MMIO_BASE as i64;

/// The model's output: cycle/instruction totals, phase attribution and
/// device activity for the energy table.
#[derive(Debug, Clone)]
pub struct Estimate {
    pub cycles: u64,
    pub instret: u64,
    pub phases: PhaseBreakdown,
    pub counts: ActivityCounts,
    /// The walked `(id, end_cycle)` marker stream the phases were
    /// attributed from — same shape as the cycle engine's MMIO stream,
    /// so the telemetry exporter renders both engines identically.
    pub markers: Vec<(u32, u64)>,
}

/// Instruction count of `Asm::li` for a value (lui+addi or single addi) —
/// shared with the assembler so the split rule cannot diverge.
fn li_len(v: i64) -> u64 {
    crate::compiler::asm::li_len(v) as u64
}

/// The walker: a cycle counter plus the uDMA transfer timeline.
struct Walker {
    now: u64,
    counts: ActivityCounts,
    markers: Vec<(u32, u64)>,
    dram: Dram,
    /// In-flight transfer completion cycle.
    dma_inflight: Option<u64>,
    /// Queued descriptors: (DRAM byte offset, length).
    dma_queue: VecDeque<(u32, u32)>,
    /// Completed-transfer count (MMIO_UDMA_DONE readback).
    dma_done: u32,
    /// Overlapped multi-macro schedule: per-macro groups advance the
    /// clock by the slowest macro instead of the serial sum (the modeled
    /// parallel hardware; activity counts still accumulate all work).
    overlap: bool,
}

impl Walker {
    fn new(cfg: &DramConfig) -> Self {
        Walker {
            now: 0,
            counts: ActivityCounts::default(),
            markers: Vec::new(),
            // Timing-only instance: access_latency never touches data.
            dram: Dram::new(cfg.clone(), 0),
            dma_inflight: None,
            dma_queue: VecDeque::new(),
            dma_done: 0,
            overlap: false,
        }
    }

    /// Walk one per-macro group: in the serial (ISS-mirroring) schedule
    /// the segments run back to back; in the overlapped schedule every
    /// segment starts at the group start and the clock joins at the
    /// slowest end (fires overlap, load streams split per macro).
    fn macro_group(&mut self, n_segments: usize, mut segment: impl FnMut(&mut Walker, usize)) {
        if !self.overlap {
            for i in 0..n_segments {
                segment(self, i);
            }
            return;
        }
        let start = self.now;
        let mut end = start;
        for i in 0..n_segments {
            self.now = start;
            segment(self, i);
            end = end.max(self.now);
        }
        self.now = end;
    }

    // --- instruction-class costs (cpu module timing model) --------------

    /// `n` single-cycle instructions (ALU, lui, mv, untaken side of li).
    fn alu(&mut self, n: u64) {
        self.now += n;
        self.counts.instret += n;
    }

    fn li(&mut self, v: i64) {
        self.alu(li_len(v));
    }

    /// 2-cycle load (on-chip / MMIO: no DRAM stalls in these programs).
    fn load(&mut self) {
        self.now += 2;
        self.counts.instret += 1;
    }

    fn load_dmem(&mut self) {
        self.load();
        self.counts.dmem_accesses += 1;
    }

    fn load_fm(&mut self) {
        self.load();
        self.counts.fm_reads += 1;
    }

    /// Single-cycle store (on-chip / MMIO).
    fn store(&mut self) {
        self.now += 1;
        self.counts.instret += 1;
    }

    fn store_dmem(&mut self) {
        self.store();
        self.counts.dmem_accesses += 1;
    }

    fn store_fm(&mut self) {
        self.store();
        self.counts.fm_writes += 1;
    }

    /// Conditional branch: 2 cycles taken (front-end flush), 1 not.
    fn branch(&mut self, taken: bool) {
        self.now += if taken { 2 } else { 1 };
        self.counts.instret += 1;
    }

    // --- CIM instruction events -----------------------------------------

    /// `cim_conv`: optional FM shift-in, fire on wd==0, always stores one
    /// latch word back to FM SRAM. Single cycle.
    fn cim_conv(&mut self, shift: bool, fire: bool) {
        self.now += 1;
        self.counts.instret += 1;
        if shift {
            self.counts.fm_reads += 1;
            self.counts.shifts += 1;
        }
        if fire {
            self.counts.fires += 1;
        }
        self.counts.fm_writes += 1;
    }

    /// `cim_w` sourcing the FM SRAM (mask-plane boot burst).
    fn cim_w_from_fm(&mut self) {
        self.now += 1;
        self.counts.instret += 1;
        self.counts.fm_reads += 1;
        self.counts.weight_writes += 1;
    }

    /// `cim_w` sourcing the weight SRAM (layer sign/threshold bursts).
    fn cim_w_from_wt(&mut self) {
        self.now += 1;
        self.counts.instret += 1;
        self.counts.wt_reads += 1;
        self.counts.weight_writes += 1;
    }

    /// `cim_r` draining a raw sum into DMEM (final layer).
    fn cim_r_to_dmem(&mut self) {
        self.now += 1;
        self.counts.instret += 1;
        self.counts.weight_reads += 1;
        self.counts.dmem_accesses += 1;
    }

    // --- uDMA timeline ---------------------------------------------------

    fn dma_launch(&mut self, at: u64, off: u32, len: u32) {
        let lat = self.dram.access_latency(off, len);
        self.counts.dram_bytes += len as u64;
        self.counts.udma_bytes += len as u64;
        self.dma_inflight = Some(at + lat);
    }

    /// Retire completed transfers and chain queued descriptors (the real
    /// engine does this on CPU ticks; we do it at completion cycles).
    fn dma_advance(&mut self, now: u64) {
        while let Some(done_at) = self.dma_inflight {
            if done_at > now {
                break;
            }
            self.dma_inflight = None;
            self.dma_done += 1;
            if let Some((off, len)) = self.dma_queue.pop_front() {
                self.dma_launch(done_at, off, len);
            }
        }
    }

    fn dma_busy(&mut self, now: u64) -> bool {
        self.dma_advance(now);
        self.dma_inflight.map_or(false, |d| d > now) || !self.dma_queue.is_empty()
    }

    /// Mirror of `emit_udma_start`: three li+sw register writes, then the
    /// CTRL write that launches (or enqueues) the transfer.
    fn udma_start(&mut self, src: i64, dst: i64, len: i64, dram_off: u32) {
        self.li(src);
        self.store();
        self.li(dst);
        self.store();
        self.li(len);
        self.store();
        self.li(1);
        let at = self.now; // MMIO write sees the pre-instruction clock
        if self.dma_busy(at) {
            self.dma_queue.push_back((dram_off, len as u32));
        } else {
            self.dma_launch(at, dram_off, len as u32);
        }
        self.store();
    }

    /// Mirror of `emit_udma_wait`: lw CTRL + bne poll loop.
    fn udma_wait(&mut self) {
        loop {
            let busy = self.dma_busy(self.now);
            self.load();
            if busy {
                self.branch(true);
            } else {
                self.branch(false);
                break;
            }
        }
    }

    /// Mirror of the weight-fusion descriptor poll: lw DONE + blt loop.
    fn udma_poll_done(&mut self, need: u32) {
        loop {
            self.dma_advance(self.now);
            let done = self.dma_done;
            self.load();
            if done < need {
                self.branch(true);
            } else {
                self.branch(false);
                break;
            }
        }
    }

    /// Mirror of `emit_phase`: li + MMIO store, marker at the store's
    /// pre-instruction clock (what `Bus::mmio_write` records).
    fn phase(&mut self, id: u32) {
        self.li(id as i64);
        self.markers.push((id, self.now));
        self.store();
    }

    /// Mirror of `emit_sel` (macro select: li + MMIO store).
    fn sel(&mut self, value: i64) {
        self.li(value);
        self.store();
    }
}

const SEL_BROADCAST: i64 = layout::CIM_SEL_BROADCAST as i64;

/// Mirror of `emit_boot`.
fn boot(w: &mut Walker, p: &KwsPlan, shards: &ShardPlan, opt: OptLevel) {
    w.li(MMIO); // t6 = MMIO base
    if shards.n_macros > 1 {
        w.sel(SEL_BROADCAST);
    }
    w.udma_start(
        DRAM + plan::DRAM_AUDIO as i64,
        DMEM + plan::DMEM_AUDIO as i64,
        p.audio_bytes as i64,
        plan::DRAM_AUDIO,
    );
    w.li(FM + plan::FM_ONES as i64); // a1
    w.li(weight_map::MASK_BASE as i64); // a2
    w.li((weight_map::MASK_BASE + weight_map::MASK_WORDS) as i64); // t1
    w.li(0xFFFF_FFFFu32 as i64); // t0 (the ones word)
    w.store_fm(); // sw a1, t0
    for i in 0..weight_map::MASK_WORDS {
        w.cim_w_from_fm();
        w.alu(1); // addi a2
        w.branch(i + 1 != weight_map::MASK_WORDS);
    }
    w.udma_wait(); // audio must have landed
    if opt.weight_fusion {
        for lp in &p.layers {
            w.udma_start(
                DRAM + lp.dram_offset as i64,
                WT + lp.wt_offset as i64,
                lp.stream_bytes() as i64,
                lp.dram_offset,
            );
        }
    }
    w.phase(1);
}

/// Mirror of `emit_preprocess`.
fn preprocess(w: &mut Walker, t_frames: usize, c: usize) {
    let wpr = c / 32;
    w.li(DMEM + plan::DMEM_AUDIO as i64); // s0
    w.li(FM + plan::FM_BUF_A as i64); // s1
    w.li(t_frames as i64); // s2
    for t in 0..t_frames {
        w.li(DMEM + plan::DMEM_THR as i64); // s4
        for wd in 0..wpr {
            w.li(0); // t3 = 0
            for cbit in 0..32 {
                w.load_dmem(); // lh x
                w.load_dmem(); // lh prev
                w.alu(4); // slli slli sub sub (pre-emphasis)
                w.alu(3); // srai xor sub (|y|)
                w.load_dmem(); // lw threshold
                w.alu(1); // slt
                if cbit > 0 {
                    w.alu(1); // slli into bit position
                }
                w.alu(1); // or into the word accumulator
            }
            w.li(DMEM + plan::DMEM_FLIP as i64 + (wd * 4) as i64); // li t4
            w.load_dmem(); // lw flip word
            w.alu(1); // xor
            w.store_fm(); // sw packed word
        }
        w.alu(3); // addi s1, s0, s2
        w.branch(t + 1 != t_frames);
    }
    w.phase(2);
}

/// Mirror of `emit_weight_phase` (per-macro shard bursts; the overlapped
/// schedule runs the macros' load streams concurrently).
fn weight_phase(w: &mut Walker, p: &KwsPlan, shards: &ShardPlan, i: usize, opt: OptLevel) {
    let lp = &p.layers[i];
    let multi = shards.n_macros > 1;
    if opt.weight_fusion {
        w.li(i as i64 + 2); // t1 = needed done-count
        w.udma_poll_done(i as u32 + 2);
    } else {
        w.udma_start(
            DRAM + lp.dram_offset as i64,
            WT + lp.wt_offset as i64,
            lp.stream_bytes() as i64,
            lp.dram_offset,
        );
        w.udma_wait();
    }
    let aw = lp.window_words;
    let groups = shards.layers[i].non_empty();
    w.macro_group(groups.len(), |w, g| {
        let (m, c0, c1) = groups[g];
        let cols = c1 - c0;
        if multi {
            w.sel(m as i64);
        }
        w.li(WT + lp.wt_offset as i64 + (4 * c0 * aw) as i64); // a1
        w.li(weight_map::SIGN_BASE as i64); // a2
        w.li(cols as i64); // s5
        for col in 0..cols {
            for _ in 0..aw {
                w.cim_w_from_wt();
            }
            w.alu(3); // addi a1, a2, s5
            w.branch(col + 1 != cols);
        }
        if lp.th_words > 0 {
            if multi {
                w.li(WT + lp.wt_offset as i64 + (4 * (lp.sign_words + c0)) as i64); // a1
            }
            w.li(weight_map::TH_BASE as i64); // a2
            w.li(cols as i64); // s5
            for j in 0..cols {
                w.cim_w_from_wt();
                w.alu(3); // addi a1, a2, s5
                w.branch(j + 1 != cols);
            }
        }
    });
    w.phase(10 + i as u32);
}

/// Mirror of `emit_sign_burst` (fused: rectangle at `row_base`).
fn sign_burst(w: &mut Walker, p: &KwsPlan, shards: &ShardPlan, i: usize, row_base: usize) {
    let lp = &p.layers[i];
    let aw = lp.window_words;
    let multi = shards.n_macros > 1;
    let groups = shards.layers[i].non_empty();
    w.macro_group(groups.len(), |w, g| {
        let (m, c0, c1) = groups[g];
        let cols = c1 - c0;
        if multi {
            w.sel(m as i64);
        }
        w.li(WT + lp.wt_offset as i64 + (4 * c0 * aw) as i64);
        w.li((weight_map::SIGN_BASE + row_base) as i64);
        w.li(cols as i64);
        for col in 0..cols {
            for _ in 0..aw {
                w.cim_w_from_wt();
            }
            w.alu(3);
            w.branch(col + 1 != cols);
        }
    });
}

/// Mirror of `emit_fused_weight_phase`: streamed sign bursts plus the
/// per-inference threshold re-burst — no DRAM traffic.
fn fused_weight_phase(w: &mut Walker, p: &KwsPlan, shards: &ShardPlan, i: usize, fp: &FusionPlan) {
    let lp = &p.layers[i];
    let multi = shards.n_macros > 1;
    if !fp.resident[i] {
        sign_burst(w, p, shards, i, fp.stream_base);
    }
    if lp.th_words > 0 {
        let groups = shards.layers[i].non_empty();
        w.macro_group(groups.len(), |w, g| {
            let (m, c0, c1) = groups[g];
            let cols = c1 - c0;
            if multi {
                w.sel(m as i64);
            }
            w.li(WT + lp.wt_offset as i64 + (4 * (lp.sign_words + c0)) as i64);
            w.li(weight_map::TH_BASE as i64);
            w.li(cols as i64);
            for j in 0..cols {
                w.cim_w_from_wt();
                w.alu(3);
                w.branch(j + 1 != cols);
            }
        });
    }
    w.phase(10 + i as u32);
}

/// Mirror of `emit_conv_layer` (sharded: interleaved per-macro fires and
/// drains; the overlapped schedule fires the macros concurrently).
fn conv_layer(
    w: &mut Walker,
    p: &KwsPlan,
    shards: &ShardPlan,
    i: usize,
    opt: OptLevel,
    fusion: Option<&FusionPlan>,
) {
    let lp = &p.layers[i];
    let s = lp.s_words;
    let o = lp.o_words;
    let t_len = lp.t_in;
    let fused_pool = opt.conv_pool_pipeline && lp.pooled;
    let multi = shards.n_macros > 1;
    let groups = shards.layers[i].non_empty();

    if multi {
        w.sel(SEL_BROADCAST);
    }
    let cfg = CimConfig {
        mode: Mode::X,
        pool_or: fused_pool,
        window_words: lp.window_words as u8,
        row_base: fusion.map_or(0, |f| f.row_base[i] as u8),
        col_base: 0,
    };
    w.li(cfg.to_bits() as i64);
    w.store(); // MMIO_CIM_CFG

    let conv_dst = if fused_pool || !lp.pooled {
        FM + p.out_buf(i) as i64
    } else {
        FM + plan::FM_PREPOOL as i64
    };
    w.li(FM + p.in_buf(i) as i64); // a0
    w.li(FM + plan::FM_SCRATCH as i64); // a2
    w.li(conv_dst); // a3
    w.li(FM + plan::FM_ZERO as i64); // a1
    for _ in 0..s {
        w.cim_conv(true, false); // prefill: zero row
    }
    for _ in 0..2 * s {
        w.cim_conv(true, false); // prefill: rows 0, 1
    }
    w.alu(1); // addi a0

    for t in 0..t_len {
        let drains = if fused_pool { t % 2 == 1 } else { true };
        if drains {
            if t == 1 && fused_pool && fusion.is_some() {
                w.phase(40 + i as u32); // first pooled drain (overlap start)
            }
            w.macro_group(groups.len(), |w, g| {
                let (m, c0, c1) = groups[g];
                if multi {
                    w.sel(m as i64);
                }
                w.cim_conv(false, true); // wd=0 fire + real store
                for _ in 1..(c1 - c0).div_ceil(32) {
                    w.cim_conv(false, false);
                }
            });
            w.alu(1); // addi a3
        } else {
            w.macro_group(groups.len(), |w, g| {
                let (m, ..) = groups[g];
                if multi {
                    w.sel(m as i64);
                }
                w.cim_conv(false, true); // fire, dummy store
            });
        }
        if t + 2 <= t_len && multi {
            w.sel(SEL_BROADCAST);
        }
        if t + 2 < t_len {
            for _ in 0..s {
                w.cim_conv(true, false);
            }
            w.alu(1); // addi a0
        } else if t + 2 == t_len {
            for _ in 0..s {
                w.cim_conv(true, false); // boundary zero row
            }
        }
    }

    if lp.pooled && !fused_pool {
        // RISC-V OR pooling pass (Fig. 7 baseline).
        w.li(FM + plan::FM_PREPOOL as i64); // s0
        w.li(FM + p.out_buf(i) as i64); // s1
        w.li(lp.t_out as i64); // s2
        for t in 0..lp.t_out {
            for _ in 0..o {
                w.load_fm();
                w.load_fm();
                w.alu(1); // or
                w.store_fm();
            }
            w.alu(3); // addi s0, s1, s2
            w.branch(t + 1 != lp.t_out);
        }
    }

    if !opt.layer_fusion && i + 1 < p.layers.len() {
        // Baseline FM round trip through DRAM (Fig. 6 baseline).
        let out = p.out_buf(i) as i64;
        let bytes = lp.out_bytes() as i64;
        w.udma_start(FM + out, DRAM + plan::DRAM_FM_SPILL as i64, bytes, plan::DRAM_FM_SPILL);
        w.udma_wait();
        w.udma_start(DRAM + plan::DRAM_FM_SPILL as i64, FM + out, bytes, plan::DRAM_FM_SPILL);
        w.udma_wait();
    }
    w.phase(30 + i as u32);
}

/// Mirror of `emit_final_layer` (sharded: per-macro fire + raw drains).
fn final_layer(w: &mut Walker, p: &KwsPlan, shards: &ShardPlan, n: usize, fusion: Option<&FusionPlan>) {
    let i = p.layers.len() - 1;
    let lp = &p.layers[i];
    let s = lp.s_words;
    let t_len = lp.t_in;
    let multi = shards.n_macros > 1;
    let groups = shards.layers[i].non_empty();

    if multi {
        w.sel(SEL_BROADCAST);
    }
    let cfg = CimConfig {
        mode: Mode::X,
        pool_or: false,
        window_words: lp.window_words as u8,
        row_base: fusion.map_or(0, |f| f.row_base[i] as u8),
        col_base: 0,
    };
    w.li(cfg.to_bits() as i64);
    w.store(); // MMIO_CIM_CFG

    w.li(FM + p.in_buf(i) as i64); // a0
    w.li(FM + plan::FM_ZERO as i64); // a1
    w.li(FM + plan::FM_SCRATCH as i64); // a2
    w.li(DMEM + plan::DMEM_RAWDUMP as i64); // a3
    for _ in 0..s {
        w.cim_conv(true, false);
    }
    for _ in 0..2 * s {
        w.cim_conv(true, false);
    }
    w.alu(1); // addi a0
    w.li(weight_map::RAW_BASE as i64); // s3

    for t in 0..t_len {
        w.macro_group(groups.len(), |w, g| {
            let (m, c0, c1) = groups[g];
            if multi {
                w.sel(m as i64);
            }
            w.cim_conv(false, true); // fire, dummy store
            w.alu(1); // mv a1, s3
            for _ in 0..c1 - c0 {
                w.cim_r_to_dmem();
            }
            w.li(FM + plan::FM_ZERO as i64); // restore a1
        });
        w.alu(1); // addi a3
        if t + 2 <= t_len && multi {
            w.sel(SEL_BROADCAST);
        }
        if t + 2 < t_len {
            for _ in 0..s {
                w.cim_conv(true, false);
            }
            w.alu(1); // addi a0
        } else if t + 2 == t_len {
            for _ in 0..s {
                w.cim_conv(true, false);
            }
        }
    }

    // GAP accumulate.
    w.li(DMEM + plan::DMEM_RAWDUMP as i64); // s0
    w.li(DMEM + plan::DMEM_RESULT as i64); // s1
    for _ in 0..n {
        w.store_dmem(); // zero the accumulators
    }
    w.li(t_len as i64); // s2
    for t in 0..t_len {
        for _ in 0..n {
            w.load_dmem();
            w.load_dmem();
            w.alu(1); // add
            w.store_dmem();
        }
        w.alu(2); // addi s0, s2
        w.branch(t + 1 != t_len);
    }
    w.phase(30 + i as u32);
}

/// Estimate cycles/instret/phases/activity for one inference of this
/// program (inference latency is data-independent: every branch in the
/// emitted code is a loop counter, never a value compare). Sharded
/// programs are mirrored instruction for instruction, including the
/// serial per-macro select/fire interleave the single-issue core emits.
pub fn estimate(program: &Program, dram_cfg: &DramConfig) -> Estimate {
    walk(program, dram_cfg, false)
}

/// The shard-aware overlapped schedule: same walk, but per-macro groups
/// (weight load streams, fires, drains) advance the clock by the slowest
/// macro instead of the serial sum — what a multi-macro chip with
/// per-macro load/drain engines would achieve. Equals [`estimate`] for
/// single-macro programs; the headroom it reports is surfaced by
/// `cimrv run --macros N`.
pub fn estimate_overlapped(program: &Program, dram_cfg: &DramConfig) -> Estimate {
    walk(program, dram_cfg, true)
}

/// Mirror of the fused per-inference section (PC `entry` onward). The
/// one-time setup section is *not* walked: the estimate reports the
/// steady-state inference latency, which is what the fused optimization
/// changes (setup amortizes over the deployment lifetime).
fn fused_inference(w: &mut Walker, p: &KwsPlan, shards: &ShardPlan, opt: OptLevel, n: usize) {
    let fp = FusionPlan::new(p);
    let multi = shards.n_macros > 1;
    w.li(MMIO); // t6
    if multi {
        w.sel(SEL_BROADCAST);
    }
    w.udma_start(
        DRAM + plan::DRAM_AUDIO as i64,
        DMEM + plan::DMEM_AUDIO as i64,
        p.audio_bytes as i64,
        plan::DRAM_AUDIO,
    );
    w.udma_wait();
    w.phase(1);
    let t = p.layers[0].t_in;
    let c = p.layers[0].s_words * 32;
    preprocess(w, t, c);
    for i in 0..p.layers.len() {
        fused_weight_phase(w, p, shards, i, &fp);
        if p.layers[i].binarized {
            conv_layer(w, p, shards, i, opt, Some(&fp));
        } else {
            final_layer(w, p, shards, n, Some(&fp));
        }
    }
}

/// Mirror of `emit_input_weight_phase` (input-axis sharding).
fn input_weight_phase(w: &mut Walker, p: &KwsPlan, shards: &ShardPlan, i: usize) {
    let lp = &p.layers[i];
    let multi = shards.n_macros > 1;
    w.udma_start(
        DRAM + lp.dram_offset as i64,
        WT + lp.wt_offset as i64,
        lp.stream_bytes() as i64,
        lp.dram_offset,
    );
    w.udma_wait();
    let s = lp.s_words;
    let k = lp.window_words / s;
    let groups = shards.layers[i].non_empty();
    w.macro_group(groups.len(), |w, g| {
        let (m, c0, c1) = groups[g];
        let sl = (c1 - c0) / 32;
        if multi {
            w.sel(m as i64);
        }
        w.li(WT + lp.wt_offset as i64);
        w.li(weight_map::SIGN_BASE as i64);
        w.li(lp.c_out as i64);
        for col in 0..lp.c_out {
            for _ in 0..k * sl {
                w.cim_w_from_wt();
            }
            w.alu(3);
            w.branch(col + 1 != lp.c_out);
        }
    });
    if lp.th_words > 0 {
        let off = lp.dram_offset + 4 * lp.sign_words as u32;
        w.udma_start(
            DRAM + off as i64,
            DMEM + plan::DMEM_SLICE_TH as i64,
            (4 * lp.th_words) as i64,
            off,
        );
        w.udma_wait();
    }
    w.phase(10 + i as u32);
}

/// Mirror of `emit_input_conv_layer`.
fn input_conv_layer(w: &mut Walker, p: &KwsPlan, shards: &ShardPlan, i: usize, opt: OptLevel) {
    let lp = &p.layers[i];
    let s = lp.s_words;
    let o = lp.o_words;
    let t_len = lp.t_in;
    let c_out = lp.c_out;
    let multi = shards.n_macros > 1;
    let groups = shards.layers[i].non_empty();
    let k = lp.window_words / s;

    for &(m, c0, c1) in &groups {
        let sl = (c1 - c0) / 32;
        if multi {
            w.sel(m as i64);
        }
        let cfg = CimConfig {
            mode: Mode::X,
            pool_or: false,
            window_words: (k * sl) as u8,
            row_base: 0,
            col_base: 0,
        };
        w.li(cfg.to_bits() as i64);
        w.store();
    }
    w.li(FM + p.in_buf(i) as i64); // a0
    w.li(FM + plan::FM_ZERO as i64); // a1
    w.li(FM + plan::FM_SCRATCH as i64); // a2
    w.li(weight_map::RAW_BASE as i64); // s3
    w.li(DMEM + plan::DMEM_SLICE_TH as i64); // s4
    let dst =
        if lp.pooled { FM + plan::FM_PREPOOL as i64 } else { FM + p.out_buf(i) as i64 };
    w.li(dst); // s1
    w.macro_group(groups.len(), |w, g| {
        let (m, c0, c1) = groups[g];
        let sl = (c1 - c0) / 32;
        if multi {
            w.sel(m as i64);
        }
        for _ in 0..3 * sl {
            w.cim_conv(true, false); // prefill: zero row + rows 0, 1
        }
    });
    w.alu(1); // addi a0

    for t in 0..t_len {
        w.macro_group(groups.len(), |w, g| {
            let (m, ..) = groups[g];
            if multi {
                w.sel(m as i64);
            }
            w.cim_conv(false, true); // fire, dummy store
            w.li(DMEM + plan::DMEM_RAWPART as i64 + (4 * g * c_out) as i64); // a3
            w.alu(1); // mv a1, s3
            for c in 0..c_out {
                if c > 0 && c % 128 == 0 {
                    w.alu(1); // addi a3 (imm_d range)
                }
                w.cim_r_to_dmem();
            }
            w.li(FM + plan::FM_ZERO as i64); // restore a1
        });
        for gi in 1..groups.len() {
            w.li(DMEM + plan::DMEM_RAWPART as i64); // s0
            w.li(DMEM + plan::DMEM_RAWPART as i64 + (4 * gi * c_out) as i64); // s5
            w.li(c_out as i64); // s2
            for j in 0..c_out {
                w.load_dmem();
                w.load_dmem();
                w.alu(1); // add
                w.store_dmem();
                w.alu(3); // addi s0, s5, s2
                w.branch(j + 1 != c_out);
            }
        }
        w.li(DMEM + plan::DMEM_RAWPART as i64); // s0
        for wd in 0..o {
            w.li(0); // t3
            for bit in 0..32.min(c_out - wd * 32) {
                w.load_dmem();
                w.load_dmem();
                w.alu(1); // slt
                if bit > 0 {
                    w.alu(1); // slli
                }
                w.alu(1); // or
            }
            w.store_fm();
        }
        w.alu(1); // addi s1
        if t + 2 < t_len {
            w.macro_group(groups.len(), |w, g| {
                let (m, c0, c1) = groups[g];
                let sl = (c1 - c0) / 32;
                if multi {
                    w.sel(m as i64);
                }
                for _ in 0..sl {
                    w.cim_conv(true, false);
                }
            });
            w.alu(1); // addi a0
        } else if t + 2 == t_len {
            w.macro_group(groups.len(), |w, g| {
                let (m, c0, c1) = groups[g];
                let sl = (c1 - c0) / 32;
                if multi {
                    w.sel(m as i64);
                }
                for _ in 0..sl {
                    w.cim_conv(true, false);
                }
            });
        }
    }

    if lp.pooled {
        w.li(FM + plan::FM_PREPOOL as i64);
        w.li(FM + p.out_buf(i) as i64);
        w.li(lp.t_out as i64);
        for t in 0..lp.t_out {
            for _ in 0..o {
                w.load_fm();
                w.load_fm();
                w.alu(1);
                w.store_fm();
            }
            w.alu(3);
            w.branch(t + 1 != lp.t_out);
        }
    }
    if !opt.layer_fusion && i + 1 < p.layers.len() {
        let out = p.out_buf(i) as i64;
        let bytes = lp.out_bytes() as i64;
        w.udma_start(FM + out, DRAM + plan::DRAM_FM_SPILL as i64, bytes, plan::DRAM_FM_SPILL);
        w.udma_wait();
        w.udma_start(DRAM + plan::DRAM_FM_SPILL as i64, FM + out, bytes, plan::DRAM_FM_SPILL);
        w.udma_wait();
    }
    w.phase(30 + i as u32);
}

/// Mirror of `emit_input_final_layer`.
fn input_final_layer(w: &mut Walker, p: &KwsPlan, shards: &ShardPlan, n: usize) {
    let i = p.layers.len() - 1;
    let lp = &p.layers[i];
    let s = lp.s_words;
    let t_len = lp.t_in;
    let multi = shards.n_macros > 1;
    let groups = shards.layers[i].non_empty();
    let k = lp.window_words / s;

    for &(m, c0, c1) in &groups {
        let sl = (c1 - c0) / 32;
        if multi {
            w.sel(m as i64);
        }
        let cfg = CimConfig {
            mode: Mode::X,
            pool_or: false,
            window_words: (k * sl) as u8,
            row_base: 0,
            col_base: 0,
        };
        w.li(cfg.to_bits() as i64);
        w.store();
    }
    w.li(FM + p.in_buf(i) as i64); // a0
    w.li(FM + plan::FM_ZERO as i64); // a1
    w.li(FM + plan::FM_SCRATCH as i64); // a2
    w.li(weight_map::RAW_BASE as i64); // s3
    w.li(DMEM + plan::DMEM_RAWDUMP as i64); // s1
    w.macro_group(groups.len(), |w, g| {
        let (m, c0, c1) = groups[g];
        let sl = (c1 - c0) / 32;
        if multi {
            w.sel(m as i64);
        }
        for _ in 0..3 * sl {
            w.cim_conv(true, false);
        }
    });
    w.alu(1); // addi a0

    for t in 0..t_len {
        w.macro_group(groups.len(), |w, g| {
            let (m, ..) = groups[g];
            if multi {
                w.sel(m as i64);
            }
            w.cim_conv(false, true);
            w.li(DMEM + plan::DMEM_RAWPART as i64); // a3
            w.alu(1); // mv a1, s3
            for _ in 0..n {
                w.cim_r_to_dmem();
            }
            w.li(FM + plan::FM_ZERO as i64);
        });
        w.li(DMEM + plan::DMEM_RAWPART as i64); // a3 reload
        for _ in 0..n {
            w.load_dmem();
            for _ in 1..groups.len() {
                w.load_dmem();
                w.alu(1);
            }
            w.store_dmem();
        }
        w.alu(1); // addi s1
        if t + 2 < t_len {
            w.macro_group(groups.len(), |w, g| {
                let (m, c0, c1) = groups[g];
                let sl = (c1 - c0) / 32;
                if multi {
                    w.sel(m as i64);
                }
                for _ in 0..sl {
                    w.cim_conv(true, false);
                }
            });
            w.alu(1);
        } else if t + 2 == t_len {
            w.macro_group(groups.len(), |w, g| {
                let (m, c0, c1) = groups[g];
                let sl = (c1 - c0) / 32;
                if multi {
                    w.sel(m as i64);
                }
                for _ in 0..sl {
                    w.cim_conv(true, false);
                }
            });
        }
    }

    w.li(DMEM + plan::DMEM_RAWDUMP as i64);
    w.li(DMEM + plan::DMEM_RESULT as i64);
    for _ in 0..n {
        w.store_dmem();
    }
    w.li(t_len as i64);
    for t in 0..t_len {
        for _ in 0..n {
            w.load_dmem();
            w.load_dmem();
            w.alu(1);
            w.store_dmem();
        }
        w.alu(2);
        w.branch(t + 1 != t_len);
    }
    w.phase(30 + i as u32);
}

fn walk(program: &Program, dram_cfg: &DramConfig, overlap: bool) -> Estimate {
    let p = &program.plan;
    let shards = &program.shards;
    let mut w = Walker::new(dram_cfg);
    w.overlap = overlap;

    if program.opt.fused {
        fused_inference(&mut w, p, shards, program.opt, program.n_classes);
    } else if shards.axis == ShardAxis::Input {
        // Input-axis programs boot without the weight-fusion descriptor
        // chain (see `build_kws_program_input_sharded`).
        let serial = OptLevel { weight_fusion: false, ..program.opt };
        boot(&mut w, p, shards, serial);
        let t = p.layers[0].t_in;
        let c = p.layers[0].s_words * 32;
        preprocess(&mut w, t, c);
        for i in 0..p.layers.len() {
            input_weight_phase(&mut w, p, shards, i);
            if p.layers[i].binarized {
                input_conv_layer(&mut w, p, shards, i, program.opt);
            } else {
                input_final_layer(&mut w, p, shards, program.n_classes);
            }
        }
    } else {
        boot(&mut w, p, shards, program.opt);
        let t = p.layers[0].t_in;
        let c = p.layers[0].s_words * 32;
        preprocess(&mut w, t, c);
        for i in 0..p.layers.len() {
            weight_phase(&mut w, p, shards, i, program.opt);
            if p.layers[i].binarized {
                conv_layer(&mut w, p, shards, i, program.opt, None);
            } else {
                final_layer(&mut w, p, shards, program.n_classes, None);
            }
        }
    }
    // Result publication + HOST_EXIT (the halting store retires normally).
    w.li(DMEM + plan::DMEM_RESULT as i64);
    w.store();
    w.li(0);
    w.store();

    let cycles = w.now;
    let mut counts = w.counts;
    counts.cycles = cycles;
    counts.macs = counts.fires * Mode::X.macs_per_fire();
    Estimate {
        cycles,
        instret: counts.instret,
        phases: PhaseBreakdown::from_markers(&w.markers, cycles),
        counts,
        markers: w.markers,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compiler::build_kws_program;
    use crate::model::KwsModel;

    #[test]
    fn li_len_matches_assembler_split() {
        assert_eq!(li_len(0), 1);
        assert_eq!(li_len(2047), 1);
        assert_eq!(li_len(-2048), 1);
        assert_eq!(li_len(2048), 2); // lui + addi
        assert_eq!(li_len(0x2000_0000), 1); // lui only
        assert_eq!(li_len(0x2000_0100), 2);
        assert_eq!(li_len(0xFFFF_FFFFu32 as i64), 1); // -1 fits addi
    }

    #[test]
    fn phases_partition_total() {
        let m = KwsModel::synthetic(1);
        for (_, opt) in OptLevel::ladder() {
            let prog = build_kws_program(&m, opt).unwrap();
            let e = estimate(&prog, &DramConfig::default());
            assert!(e.cycles > 0 && e.instret > 0);
            assert_eq!(e.phases.total(), e.cycles);
            assert!(e.phases.boot > 0 && e.phases.preprocess > 0);
            assert!(e.phases.weights > 0 && e.phases.conv > 0);
        }
    }

    #[test]
    fn estimated_ladder_is_monotone() {
        // The analytical model must reproduce the paper's ordering: each
        // added optimization strictly reduces estimated cycles.
        let m = KwsModel::synthetic(4);
        let mut prev = u64::MAX;
        for (name, opt) in OptLevel::ladder() {
            let prog = build_kws_program(&m, opt).unwrap();
            let e = estimate(&prog, &DramConfig::default());
            assert!(e.cycles < prev, "{name}: {} !< {prev}", e.cycles);
            prev = e.cycles;
        }
    }

    #[test]
    fn sharded_estimates_are_consistent() {
        let m = KwsModel::synthetic(9);
        let single = estimate(
            &crate::compiler::build_kws_program(&m, OptLevel::FULL).unwrap(),
            &DramConfig::default(),
        );
        for n in 2..=4usize {
            let prog =
                crate::compiler::build_kws_program_sharded(&m, OptLevel::FULL, n).unwrap();
            let serial = estimate(&prog, &DramConfig::default());
            let overlapped = estimate_overlapped(&prog, &DramConfig::default());
            // The single-issue core pays for the interleave; the modeled
            // parallel hardware never does worse than the serial schedule.
            assert!(serial.cycles > single.cycles, "n={n}");
            assert!(overlapped.cycles <= serial.cycles, "n={n}");
            // All schedules do the same work (energy inputs identical).
            assert_eq!(serial.counts.fires, overlapped.counts.fires);
            assert_eq!(serial.instret, overlapped.instret);
            assert_eq!(serial.phases.total(), serial.cycles);
            assert_eq!(overlapped.phases.total(), overlapped.cycles);
        }
        // Overlap is a no-op for single-macro programs.
        let prog = crate::compiler::build_kws_program(&m, OptLevel::FULL).unwrap();
        assert_eq!(
            estimate_overlapped(&prog, &DramConfig::default()).cycles,
            estimate(&prog, &DramConfig::default()).cycles
        );
    }

    #[test]
    fn fused_estimate_beats_full_and_partitions() {
        let m = KwsModel::synthetic(5);
        let full = estimate(
            &build_kws_program(&m, OptLevel::FULL).unwrap(),
            &DramConfig::default(),
        );
        let prog = build_kws_program(&m, OptLevel::FUSED).unwrap();
        let fused = estimate(&prog, &DramConfig::default());
        assert!(fused.cycles < full.cycles);
        assert_eq!(fused.phases.total(), fused.cycles);
        // Steady state: audio is the only DRAM traffic.
        assert_eq!(fused.counts.dram_bytes, prog.plan.audio_bytes as u64);
        assert!(fused.counts.dram_bytes < full.counts.dram_bytes);
        // Same fires either way (the work moves, it doesn't shrink).
        assert_eq!(fused.counts.fires, full.counts.fires);
        // Pool-drain markers show up for the pooled layers.
        assert!(fused.markers.iter().any(|&(id, _)| (40..50).contains(&id)));
        // Overlapped never does worse.
        let ov = estimate_overlapped(&prog, &DramConfig::default());
        assert!(ov.cycles <= fused.cycles);
    }

    #[test]
    fn input_sharded_estimate_partitions_phases() {
        let m = KwsModel::synthetic(6);
        for n in 1..=4usize {
            let prog =
                crate::compiler::build_kws_program_input_sharded(&m, OptLevel::FULL, n).unwrap();
            let e = estimate(&prog, &DramConfig::default());
            assert_eq!(e.phases.total(), e.cycles, "n={n}");
            assert!(e.phases.boot > 0 && e.phases.preprocess > 0, "n={n}");
            assert!(e.phases.weights > 0 && e.phases.conv > 0, "n={n}");
            // Same fire count as the classic schedule: one per row position
            // per non-empty slice owner.
            let want: u64 = prog
                .plan
                .layers
                .iter()
                .enumerate()
                .map(|(i, l)| (l.t_in * prog.shards.layers[i].non_empty().len()) as u64)
                .sum();
            assert_eq!(e.counts.fires, want, "n={n}");
        }
    }

    #[test]
    fn activity_counts_are_plausible() {
        let m = KwsModel::synthetic(7);
        let prog = build_kws_program(&m, OptLevel::FULL).unwrap();
        let e = estimate(&prog, &DramConfig::default());
        // One fire per row position per layer.
        let want_fires: u64 = prog.plan.layers.iter().map(|l| l.t_in as u64).sum();
        assert_eq!(e.counts.fires, want_fires);
        // Mask-plane init plus every sign/threshold word.
        let want_w: u64 =
            weight_map::MASK_WORDS as u64 + prog.plan.total_cim_w();
        assert_eq!(e.counts.weight_writes, want_w);
        assert!(e.counts.dram_bytes >= prog.plan.total_weight_bytes());
        assert_eq!(e.counts.macs, e.counts.fires * Mode::X.macs_per_fire());
    }
}
