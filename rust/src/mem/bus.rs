//! System bus: address decode, device ownership, MMIO side effects.
//!
//! The bus owns every addressable device (SRAMs, DRAM, uDMA, the CIM
//! macro's configuration window) and prices each access in stall cycles —
//! on-chip SRAM is single-cycle (0 extra stalls), DRAM pays the timing
//! model. The 2-stage core calls into this for its LSU and fetch stages;
//! CIM instructions touch `fm`/`wt`/`cim` directly (same-cycle datapath).

use anyhow::{bail, Result};

use crate::cim::{CimConfig, CimMacro};

use super::dram::{Dram, DramConfig};
use super::layout::{self, Region};
use super::sram::Sram;
use super::udma::Udma;

/// Access width.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Width {
    Byte,
    Half,
    Word,
}

/// The SoC interconnect + devices.
#[derive(Debug, Clone)]
pub struct Bus {
    pub imem: Sram,
    pub dmem: Sram,
    pub fm: Sram,
    pub wt: Sram,
    pub dram: Dram,
    pub udma: Udma,
    /// The CIM macro bank: one macro for classic programs, N for sharded
    /// ones (`--macros N`). `cim_sel` routes CIM instructions.
    pub cims: Vec<CimMacro>,
    /// Selected macro index, or `layout::CIM_SEL_BROADCAST` (shifts,
    /// fires, weight writes and CFG go to every macro; reads and output
    /// stores fall back to macro 0).
    pub cim_sel: u32,
    /// Current cycle (SoC updates before each access batch).
    pub now: u64,
    /// Set by a HOST_EXIT write: simulation should halt.
    pub exit_code: Option<u32>,
    /// HOST_PUTC output.
    pub console: String,
    /// HOST_RESULT register: DMEM address of the program's result vector.
    pub result_addr: u32,
    /// Phase markers: (id, cycle) recorded on MMIO_HOST_PHASE writes.
    pub phases: Vec<(u32, u64)>,
    /// Cycles the CPU spent stalled on DRAM (stats).
    pub cpu_dram_stalls: u64,
}

impl Bus {
    pub fn new(dram_cfg: DramConfig) -> Self {
        Self::new_with_macros(dram_cfg, 1)
    }

    /// A bus with `n` CIM macros (the multi-macro sharded SoC).
    pub fn new_with_macros(dram_cfg: DramConfig, n: usize) -> Self {
        Bus {
            imem: Sram::new("imem", layout::IMEM_SIZE),
            dmem: Sram::new("dmem", layout::DMEM_SIZE),
            fm: Sram::new("fm", layout::FM_SIZE),
            wt: Sram::new("wt", layout::WT_SIZE),
            dram: Dram::new(dram_cfg, layout::DRAM_SIZE),
            udma: Udma::new(),
            cims: (0..n.max(1)).map(|_| CimMacro::new()).collect(),
            cim_sel: 0,
            now: 0,
            exit_code: None,
            console: String::new(),
            result_addr: 0,
            phases: Vec::new(),
            cpu_dram_stalls: 0,
        }
    }

    /// The selected macro (macro 0 under broadcast — defined so that
    /// single-macro programs behave identically whatever `cim_sel` says).
    pub fn cim(&self) -> &CimMacro {
        let i = (self.cim_sel as usize).min(self.cims.len() - 1);
        if self.cim_sel == layout::CIM_SEL_BROADCAST {
            &self.cims[0]
        } else {
            &self.cims[i]
        }
    }

    /// Mutable selected macro (macro 0 under broadcast).
    pub fn cim_mut(&mut self) -> &mut CimMacro {
        let i = if self.cim_sel == layout::CIM_SEL_BROADCAST {
            0
        } else {
            (self.cim_sel as usize).min(self.cims.len() - 1)
        };
        &mut self.cims[i]
    }

    /// Shift one word into the input buffer(s): broadcast reaches every
    /// macro (the shared input bus), otherwise only the selected one.
    pub fn cim_shift_in(&mut self, word: u32) {
        if self.cim_sel == layout::CIM_SEL_BROADCAST {
            for m in &mut self.cims {
                m.shift_in(word);
            }
        } else {
            self.cim_mut().shift_in(word);
        }
    }

    /// Fire the MAC on the selected macro (all macros under broadcast).
    pub fn cim_fire(&mut self) {
        if self.cim_sel == layout::CIM_SEL_BROADCAST {
            for m in &mut self.cims {
                m.fire();
            }
        } else {
            self.cim_mut().fire();
        }
    }

    /// `cim_w` port write: broadcast writes every macro (the boot-time
    /// mask-plane init arms all macros in one burst).
    pub fn cim_port_write(&mut self, addr: u32, value: u32) -> Result<()> {
        if self.cim_sel == layout::CIM_SEL_BROADCAST {
            for m in &mut self.cims {
                m.port_write(addr, value)?;
            }
            Ok(())
        } else {
            self.cim_mut().port_write(addr, value)
        }
    }

    /// Aggregate fire/shift/load statistics across the whole bank
    /// (energy accounting: every macro's activity costs energy).
    pub fn cim_stats_total(&self) -> crate::cim::CimStats {
        let mut total = crate::cim::CimStats::default();
        for m in &self.cims {
            total.fires += m.stats.fires;
            total.shifts += m.stats.shifts;
            total.out_words += m.stats.out_words;
            total.weight_writes += m.stats.weight_writes;
            total.weight_reads += m.stats.weight_reads;
            total.macs += m.stats.macs;
        }
        total
    }

    /// Advance time: retire a completed uDMA transfer if its deadline
    /// passed. Called by the SoC every instruction step.
    pub fn tick(&mut self, now: u64) -> Result<()> {
        self.now = now;
        self.udma
            .complete(now, &mut self.dram, &mut self.fm, &mut self.wt, &mut self.dmem)
    }

    /// Load `width` at `addr`. Returns (zero-extended value, stall cycles).
    pub fn read(&mut self, addr: u32, width: Width) -> Result<(u32, u64)> {
        let Some((region, off)) = layout::decode(addr) else {
            bail!("load from unmapped address {addr:#010x}");
        };
        let (v, stall) = match region {
            Region::Imem => (read_sram(&mut self.imem, off, width)?, 0),
            Region::Dmem => (read_sram(&mut self.dmem, off, width)?, 0),
            Region::FmSram => (read_sram(&mut self.fm, off, width)?, 0),
            Region::WtSram => (read_sram(&mut self.wt, off, width)?, 0),
            Region::Dram => {
                let bytes = width_bytes(width);
                let stall = self.dram.access_latency(off, bytes);
                self.cpu_dram_stalls += stall;
                let v = match width {
                    Width::Byte => self.dram.read_u8(off)? as u32,
                    Width::Half => {
                        (self.dram.read_u8(off)? as u32)
                            | ((self.dram.read_u8(off + 1)? as u32) << 8)
                    }
                    Width::Word => self.dram.read_u32(off)?,
                };
                (v, stall)
            }
            Region::Mmio => (self.mmio_read(off)?, 0),
        };
        Ok((v, stall))
    }

    /// Store `width` at `addr`. Returns stall cycles.
    pub fn write(&mut self, addr: u32, value: u32, width: Width) -> Result<u64> {
        let Some((region, off)) = layout::decode(addr) else {
            bail!("store to unmapped address {addr:#010x}");
        };
        match region {
            Region::Imem => bail!("store to instruction memory at {addr:#010x}"),
            Region::Dmem => write_sram(&mut self.dmem, off, value, width)?,
            Region::FmSram => write_sram(&mut self.fm, off, value, width)?,
            Region::WtSram => write_sram(&mut self.wt, off, value, width)?,
            Region::Dram => {
                let stall = self.dram.access_latency(off, width_bytes(width));
                self.cpu_dram_stalls += stall;
                match width {
                    Width::Byte => self.dram.write_u8(off, value as u8)?,
                    Width::Half => {
                        self.dram.write_u8(off, value as u8)?;
                        self.dram.write_u8(off + 1, (value >> 8) as u8)?;
                    }
                    Width::Word => self.dram.write_u32(off, value)?,
                }
                return Ok(stall);
            }
            Region::Mmio => return self.mmio_write(off, value),
        }
        Ok(0)
    }

    /// Instruction fetch (imem is single-cycle; fetching outside imem is a
    /// program bug we surface immediately).
    pub fn fetch(&mut self, pc: u32) -> Result<u32> {
        match layout::decode(pc) {
            Some((Region::Imem, off)) => self.imem.read_u32(off),
            _ => bail!("fetch from non-IMEM address {pc:#010x}"),
        }
    }

    fn mmio_read(&mut self, off: u32) -> Result<u32> {
        Ok(match off {
            layout::MMIO_UDMA_SRC => self.udma.src,
            layout::MMIO_UDMA_DST => self.udma.dst,
            layout::MMIO_UDMA_LEN => self.udma.len,
            layout::MMIO_UDMA_CTRL => self.udma.busy(self.now) as u32,
            layout::MMIO_UDMA_DONE => self.udma.done_count,
            layout::MMIO_CYCLE_LO => self.now as u32,
            layout::MMIO_CYCLE_HI => (self.now >> 32) as u32,
            layout::MMIO_CIM_CFG => self.cim().cfg.to_bits(),
            layout::MMIO_CIM_SEL => self.cim_sel,
            layout::MMIO_HOST_RESULT => self.result_addr,
            _ => bail!("MMIO read from unmapped offset {off:#x}"),
        })
    }

    fn mmio_write(&mut self, off: u32, value: u32) -> Result<u64> {
        match off {
            layout::MMIO_UDMA_SRC => self.udma.src = value,
            layout::MMIO_UDMA_DST => self.udma.dst = value,
            layout::MMIO_UDMA_LEN => self.udma.len = value,
            layout::MMIO_UDMA_CTRL => {
                if value & 1 == 1 {
                    self.udma.start(self.now, &mut self.dram)?;
                }
            }
            layout::MMIO_CIM_CFG => {
                let cfg = CimConfig::from_bits(value);
                if self.cim_sel == layout::CIM_SEL_BROADCAST {
                    for m in &mut self.cims {
                        m.cfg = cfg;
                    }
                } else {
                    self.cim_mut().cfg = cfg;
                }
            }
            layout::MMIO_CIM_SEL => {
                if value != layout::CIM_SEL_BROADCAST && value as usize >= self.cims.len() {
                    bail!(
                        "CIM_SEL {value} out of range for {} macro(s) (broadcast is {:#x})",
                        self.cims.len(),
                        layout::CIM_SEL_BROADCAST
                    );
                }
                self.cim_sel = value;
            }
            layout::MMIO_HOST_EXIT => self.exit_code = Some(value),
            layout::MMIO_HOST_PUTC => self.console.push((value & 0xFF) as u8 as char),
            layout::MMIO_HOST_RESULT => self.result_addr = value,
            layout::MMIO_HOST_PHASE => self.phases.push((value, self.now)),
            _ => bail!("MMIO write to unmapped offset {off:#x}"),
        }
        Ok(0)
    }

    /// Busy-wait helper used by the timing model: cycles until the uDMA
    /// transfer in flight completes (0 if idle).
    pub fn udma_wait_cycles(&self) -> u64 {
        match self.udma.inflight {
            Some(t) if t.done_at > self.now => t.done_at - self.now,
            _ => 0,
        }
    }
}

fn width_bytes(w: Width) -> u32 {
    match w {
        Width::Byte => 1,
        Width::Half => 2,
        Width::Word => 4,
    }
}

fn read_sram(s: &mut Sram, off: u32, w: Width) -> Result<u32> {
    Ok(match w {
        Width::Byte => s.read_u8(off)? as u32,
        Width::Half => s.read_u16(off)? as u32,
        Width::Word => s.read_u32(off)?,
    })
}

fn write_sram(s: &mut Sram, off: u32, v: u32, w: Width) -> Result<()> {
    match w {
        Width::Byte => s.write_u8(off, v as u8),
        Width::Half => s.write_u16(off, v as u16),
        Width::Word => s.write_u32(off, v),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bus() -> Bus {
        Bus::new(DramConfig::default())
    }

    #[test]
    fn sram_access_is_zero_stall() {
        let mut b = bus();
        let s = b.write(layout::FM_BASE, 0x1234, Width::Word).unwrap();
        assert_eq!(s, 0);
        let (v, s) = b.read(layout::FM_BASE, Width::Word).unwrap();
        assert_eq!((v, s), (0x1234, 0));
    }

    #[test]
    fn dram_access_stalls() {
        let mut b = bus();
        let (_, stall) = b.read(layout::DRAM_BASE, Width::Word).unwrap();
        assert!(stall > 0);
        assert_eq!(b.cpu_dram_stalls, stall);
    }

    #[test]
    fn mmio_cycle_counter() {
        let mut b = bus();
        b.tick(0x1_2345_6789).unwrap();
        let (lo, _) = b.read(layout::MMIO_BASE + layout::MMIO_CYCLE_LO, Width::Word).unwrap();
        let (hi, _) = b.read(layout::MMIO_BASE + layout::MMIO_CYCLE_HI, Width::Word).unwrap();
        assert_eq!(lo, 0x2345_6789);
        assert_eq!(hi, 1);
    }

    #[test]
    fn udma_via_mmio() {
        let mut b = bus();
        b.dram.load(0, &[1, 2, 3, 4]).unwrap();
        b.write(layout::MMIO_BASE + layout::MMIO_UDMA_SRC, layout::DRAM_BASE, Width::Word).unwrap();
        b.write(layout::MMIO_BASE + layout::MMIO_UDMA_DST, layout::WT_BASE, Width::Word).unwrap();
        b.write(layout::MMIO_BASE + layout::MMIO_UDMA_LEN, 4, Width::Word).unwrap();
        b.write(layout::MMIO_BASE + layout::MMIO_UDMA_CTRL, 1, Width::Word).unwrap();
        let (busy, _) = b.read(layout::MMIO_BASE + layout::MMIO_UDMA_CTRL, Width::Word).unwrap();
        assert_eq!(busy, 1);
        let wait = b.udma_wait_cycles();
        assert!(wait > 0);
        b.tick(b.now + wait).unwrap();
        let (v, _) = b.read(layout::WT_BASE, Width::Word).unwrap();
        assert_eq!(v, 0x0403_0201);
    }

    #[test]
    fn cim_cfg_register() {
        let mut b = bus();
        let cfg = crate::cim::CimConfig {
            mode: crate::cim::Mode::Y,
            pool_or: true,
            window_words: 6,
            row_base: 3,
            col_base: 2,
        };
        b.write(layout::MMIO_BASE + layout::MMIO_CIM_CFG, cfg.to_bits(), Width::Word).unwrap();
        assert!(matches!(b.cim().cfg.mode, crate::cim::Mode::Y));
        assert!(b.cim().cfg.pool_or);
        assert_eq!(b.cim().cfg.window_words, 6);
        assert_eq!(b.cim().cfg.row_base, 3);
        assert_eq!(b.cim().cfg.col_base, 2);
        let (v, _) = b.read(layout::MMIO_BASE + layout::MMIO_CIM_CFG, Width::Word).unwrap();
        assert_eq!(v, cfg.to_bits());
    }

    #[test]
    fn macro_select_and_broadcast() {
        let mut b = Bus::new_with_macros(DramConfig::default(), 3);
        // Broadcast shift reaches every macro; selected shift only one.
        b.write(
            layout::MMIO_BASE + layout::MMIO_CIM_SEL,
            layout::CIM_SEL_BROADCAST,
            Width::Word,
        )
        .unwrap();
        b.cim_shift_in(0xF);
        assert!(b.cims.iter().all(|m| m.stats.shifts == 1));
        b.write(layout::MMIO_BASE + layout::MMIO_CIM_SEL, 2, Width::Word).unwrap();
        b.cim_shift_in(0xF);
        assert_eq!(b.cims[2].stats.shifts, 2);
        assert_eq!(b.cims[0].stats.shifts, 1);
        // Broadcast port write arms every mask plane.
        b.write(
            layout::MMIO_BASE + layout::MMIO_CIM_SEL,
            layout::CIM_SEL_BROADCAST,
            Width::Word,
        )
        .unwrap();
        b.cim_port_write(0, 0xAA).unwrap();
        for m in &mut b.cims {
            assert_eq!(m.port_read(0).unwrap(), 0xAA);
        }
        // Out-of-range select faults (program bug surfaced immediately).
        assert!(b.write(layout::MMIO_BASE + layout::MMIO_CIM_SEL, 3, Width::Word).is_err());
        // Aggregate stats sum across the bank.
        assert_eq!(b.cim_stats_total().shifts, 4);
    }

    #[test]
    fn exit_and_console() {
        let mut b = bus();
        b.write(layout::MMIO_BASE + layout::MMIO_HOST_PUTC, 'h' as u32, Width::Word).unwrap();
        b.write(layout::MMIO_BASE + layout::MMIO_HOST_PUTC, 'i' as u32, Width::Word).unwrap();
        b.write(layout::MMIO_BASE + layout::MMIO_HOST_EXIT, 0, Width::Word).unwrap();
        assert_eq!(b.console, "hi");
        assert_eq!(b.exit_code, Some(0));
    }

    #[test]
    fn unmapped_faults() {
        let mut b = bus();
        assert!(b.read(0x7000_0000, Width::Word).is_err());
        assert!(b.write(layout::IMEM_BASE, 0, Width::Word).is_err());
        assert!(b.fetch(layout::DMEM_BASE).is_err());
    }
}
