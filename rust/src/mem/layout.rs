//! SoC memory map (paper Fig. 2: instruction memory, 256 Kb feature-map
//! SRAM, 512 Kb weight SRAM, DRAM behind the uDMA, PULPissimo-style MMIO).

/// Instruction memory base (boot vector = 0).
pub const IMEM_BASE: u32 = 0x0000_0000;
pub const IMEM_SIZE: u32 = 256 * 1024;

/// Data RAM (stack + scalars for the RISC-V pre/post-processing).
pub const DMEM_BASE: u32 = 0x1000_0000;
pub const DMEM_SIZE: u32 = 256 * 1024;

/// Feature-map SRAM: 256 Kb = 32 KiB (paper Fig. 2).
pub const FM_BASE: u32 = 0x2000_0000;
pub const FM_SIZE: u32 = 32 * 1024;

/// Weight SRAM: 512 Kb = 64 KiB (paper Fig. 2).
pub const WT_BASE: u32 = 0x3000_0000;
pub const WT_SIZE: u32 = 64 * 1024;

/// External DRAM window (model weights, input audio, baseline FM spill).
pub const DRAM_BASE: u32 = 0x4000_0000;
pub const DRAM_SIZE: u32 = 16 * 1024 * 1024;

/// MMIO device registers.
pub const MMIO_BASE: u32 = 0x5000_0000;
pub const MMIO_SIZE: u32 = 0x1000;

// --- MMIO register offsets (word-aligned) -----------------------------------

/// uDMA source address (DRAM byte address).
pub const MMIO_UDMA_SRC: u32 = 0x00;
/// uDMA destination address (on-chip byte address).
pub const MMIO_UDMA_DST: u32 = 0x04;
/// uDMA transfer length in bytes.
pub const MMIO_UDMA_LEN: u32 = 0x08;
/// Write 1 to start (enqueues a descriptor when busy — PULPissimo-style
/// linked transfers); reads as 1 while busy or descriptors pend.
pub const MMIO_UDMA_CTRL: u32 = 0x0C;
/// Completed-transfer counter (descriptor-chain progress polling).
pub const MMIO_UDMA_DONE: u32 = 0x2C;
/// Cycle counter (low 32 bits).
pub const MMIO_CYCLE_LO: u32 = 0x10;
/// Cycle counter (high 32 bits).
pub const MMIO_CYCLE_HI: u32 = 0x14;
/// CIM unit configuration — see `cim::mode::CimConfig` for the bit layout
/// (mode, pool_or, window_words, row_base, col_base).
pub const MMIO_CIM_CFG: u32 = 0x18;
/// Write: halt the simulation with this exit code.
pub const MMIO_HOST_EXIT: u32 = 0x1C;
/// Write: debug character output (trace).
pub const MMIO_HOST_PUTC: u32 = 0x20;
/// Write: address (in DMEM) where the program left its result vector.
pub const MMIO_HOST_RESULT: u32 = 0x24;
/// Write: phase marker — the bus records (value, cycle) so experiments can
/// attribute latency to preprocessing / weight / conv phases.
pub const MMIO_HOST_PHASE: u32 = 0x28;
/// CIM macro select for multi-macro (sharded) SoCs: a macro index routes
/// subsequent CIM instructions / CFG writes to that macro; the broadcast
/// value applies shifts, fires, weight writes and CFG to every macro at
/// once (the shared input bus of a multi-macro chip). Single-macro
/// programs never write it (reset value 0 selects the only macro).
pub const MMIO_CIM_SEL: u32 = 0x30;
/// Broadcast value for `MMIO_CIM_SEL`.
pub const CIM_SEL_BROADCAST: u32 = 0xFFFF_FFFF;

/// CIM_CFG bits (see `cim::mode::CimConfig::to_bits`).
pub const CIM_CFG_YMODE: u32 = 1 << 0;
pub const CIM_CFG_POOL_OR: u32 = 1 << 1;

/// Which region does a byte address fall in?
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Region {
    Imem,
    Dmem,
    FmSram,
    WtSram,
    Dram,
    Mmio,
}

/// Decode an address to (region, offset). `None` for unmapped holes.
pub fn decode(addr: u32) -> Option<(Region, u32)> {
    match addr {
        _ if (IMEM_BASE..IMEM_BASE + IMEM_SIZE).contains(&addr) => {
            Some((Region::Imem, addr - IMEM_BASE))
        }
        _ if (DMEM_BASE..DMEM_BASE + DMEM_SIZE).contains(&addr) => {
            Some((Region::Dmem, addr - DMEM_BASE))
        }
        _ if (FM_BASE..FM_BASE + FM_SIZE).contains(&addr) => Some((Region::FmSram, addr - FM_BASE)),
        _ if (WT_BASE..WT_BASE + WT_SIZE).contains(&addr) => Some((Region::WtSram, addr - WT_BASE)),
        _ if (DRAM_BASE..DRAM_BASE + DRAM_SIZE).contains(&addr) => {
            Some((Region::Dram, addr - DRAM_BASE))
        }
        _ if (MMIO_BASE..MMIO_BASE + MMIO_SIZE).contains(&addr) => Some((Region::Mmio, addr - MMIO_BASE)),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decode_regions() {
        assert_eq!(decode(0), Some((Region::Imem, 0)));
        assert_eq!(decode(FM_BASE + 4), Some((Region::FmSram, 4)));
        assert_eq!(decode(WT_BASE + WT_SIZE - 1), Some((Region::WtSram, WT_SIZE - 1)));
        assert_eq!(decode(DRAM_BASE), Some((Region::Dram, 0)));
        assert_eq!(decode(MMIO_BASE + MMIO_UDMA_CTRL), Some((Region::Mmio, 0x0C)));
        assert_eq!(decode(0x6000_0000), None);
        assert_eq!(decode(FM_BASE + FM_SIZE), None);
    }

    #[test]
    fn sram_sizes_match_paper() {
        assert_eq!(FM_SIZE * 8, 256 * 1024); // 256 Kb
        assert_eq!(WT_SIZE * 8, 512 * 1024); // 512 Kb
    }
}
