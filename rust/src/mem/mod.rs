//! On-chip and off-chip memory subsystem.
//!
//! * [`layout`] — the SoC memory map (instruction / data / feature-map /
//!   weight SRAMs, DRAM window, MMIO).
//! * [`sram`]   — single-cycle on-chip SRAM banks with access accounting.
//! * [`dram`]   — DDR4-like bank/row timing model (the latency source the
//!   paper's three optimizations attack).
//! * [`udma`]   — the paper's "uDAM" engine: CPU-free bulk DRAM -> weight
//!   SRAM transfers, overlapped with CIM compute (weight fusion).
//! * [`bus`]    — address decode + MMIO device registers.

pub mod bus;
pub mod dram;
pub mod layout;
pub mod sram;
pub mod udma;
