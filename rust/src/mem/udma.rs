//! The paper's "uDAM" (micro-DMA) engine: bulk DRAM -> on-chip transfers
//! without CPU intervention, so weight loading can be pipelined with CIM
//! convolution (weight fusion, Fig. 8).
//!
//! Model: a transfer is admitted instantly (register write) and completes
//! at `start_cycle + dram_latency(len)`; while busy, the engine rejects new
//! programming. The data movement itself is applied lazily when the
//! transfer completes (the simulator's clock only observes memory *after*
//! completion because the CPU polls `MMIO_UDMA_CTRL`).

use anyhow::{bail, Result};

use super::dram::Dram;
use super::layout::{self, Region};
use super::sram::Sram;

/// One programmed transfer.
#[derive(Debug, Clone, Copy)]
pub struct Transfer {
    pub src: u32,
    pub dst: u32,
    pub len: u32,
    pub done_at: u64,
}

/// A queued descriptor (PULPissimo-style linked transfers: software
/// enqueues several; the engine processes them serially with no CPU
/// involvement — this is what lets weight fusion prefetch the whole
/// model's streams behind the preprocessing phase).
#[derive(Debug, Clone, Copy)]
pub struct Descriptor {
    pub src: u32,
    pub dst: u32,
    pub len: u32,
}

/// Maximum descriptor-chain depth.
pub const QUEUE_DEPTH: usize = 16;

/// uDMA engine state.
#[derive(Debug, Clone, Default)]
pub struct Udma {
    /// Staged register file.
    pub src: u32,
    pub dst: u32,
    pub len: u32,
    /// In-flight transfer, if any.
    pub inflight: Option<Transfer>,
    /// Pending descriptor chain.
    pub queue: std::collections::VecDeque<Descriptor>,
    /// Completed-transfer counter (MMIO_UDMA_DONE readback).
    pub done_count: u32,
    /// Stats.
    pub transfers: u64,
    pub bytes: u64,
    /// Cycles the engine spent busy (for energy + utilization reporting).
    pub busy_cycles: u64,
}

impl Udma {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn busy(&self, now: u64) -> bool {
        !self.queue.is_empty() || matches!(self.inflight, Some(t) if t.done_at > now)
    }

    /// Start the staged transfer at cycle `now`: launches immediately when
    /// idle, otherwise appends to the descriptor chain. Returns the
    /// (estimated) completion cycle of the launched transfer, or 0 when
    /// queued.
    pub fn start(&mut self, now: u64, dram: &mut Dram) -> Result<u64> {
        if self.busy(now) {
            if self.queue.len() >= QUEUE_DEPTH {
                bail!("uDMA descriptor queue overflow");
            }
            self.queue.push_back(Descriptor { src: self.src, dst: self.dst, len: self.len });
            return Ok(0);
        }
        self.launch(now, dram)
    }

    /// Launch the staged registers as a transfer (engine idle).
    fn launch(&mut self, now: u64, dram: &mut Dram) -> Result<u64> {
        if self.len == 0 {
            bail!("uDMA zero-length transfer");
        }
        // Validate endpoints: src must be DRAM, dst on-chip (or the
        // reverse for FM spill in the no-fusion baseline).
        let src_r = layout::decode(self.src).map(|(r, _)| r);
        let dst_r = layout::decode(self.dst).map(|(r, _)| r);
        let ok = matches!(
            (src_r, dst_r),
            (Some(Region::Dram), Some(Region::WtSram))
                | (Some(Region::Dram), Some(Region::FmSram))
                | (Some(Region::Dram), Some(Region::Dmem))
                | (Some(Region::FmSram), Some(Region::Dram))
                | (Some(Region::Dmem), Some(Region::Dram))
        );
        if !ok {
            bail!(
                "uDMA endpoints unsupported: {:#x} -> {:#x} ({src_r:?} -> {dst_r:?})",
                self.src,
                self.dst
            );
        }
        let dram_off = if src_r == Some(Region::Dram) {
            self.src - layout::DRAM_BASE
        } else {
            self.dst - layout::DRAM_BASE
        };
        let cycles = dram.access_latency(dram_off, self.len);
        let t = Transfer { src: self.src, dst: self.dst, len: self.len, done_at: now + cycles };
        self.inflight = Some(t);
        self.transfers += 1;
        self.bytes += self.len as u64;
        self.busy_cycles += cycles;
        Ok(t.done_at)
    }

    /// Apply the data movement of completed transfers and launch queued
    /// descriptors (call whenever the clock advances). Idempotent.
    pub fn complete(
        &mut self,
        now: u64,
        dram: &mut Dram,
        fm: &mut Sram,
        wt: &mut Sram,
        dmem: &mut Sram,
    ) -> Result<()> {
        loop {
            self.complete_one(now, dram, fm, wt, dmem)?;
            // Chain: launch the next descriptor at the finish time of the
            // previous transfer.
            if self.inflight.is_none() {
                if let Some(d) = self.queue.pop_front() {
                    self.src = d.src;
                    self.dst = d.dst;
                    self.len = d.len;
                    // The next transfer starts when the previous ended; we
                    // conservatively start it "now" (the poll quantum).
                    self.launch(now, dram)?;
                    continue;
                }
            }
            return Ok(());
        }
    }

    fn complete_one(
        &mut self,
        now: u64,
        dram: &mut Dram,
        fm: &mut Sram,
        wt: &mut Sram,
        dmem: &mut Sram,
    ) -> Result<()> {
        let Some(t) = self.inflight else { return Ok(()) };
        if t.done_at > now {
            return Ok(());
        }
        let (src_r, src_off) = layout::decode(t.src).unwrap();
        let (dst_r, dst_off) = layout::decode(t.dst).unwrap();
        // Byte-wise copy through a staging buffer (lengths are a few tens
        // of KB at most; this is host-side bookkeeping, not modeled time).
        let mut buf = vec![0u8; t.len as usize];
        match src_r {
            Region::Dram => buf.copy_from_slice(dram.slice(src_off, t.len)?),
            Region::FmSram => buf.copy_from_slice(&fm.bytes()[src_off as usize..(src_off + t.len) as usize]),
            Region::Dmem => buf.copy_from_slice(&dmem.bytes()[src_off as usize..(src_off + t.len) as usize]),
            _ => bail!("uDMA bad src region"),
        }
        match dst_r {
            Region::WtSram => wt.load(dst_off, &buf)?,
            Region::FmSram => fm.load(dst_off, &buf)?,
            Region::Dmem => dmem.load(dst_off, &buf)?,
            Region::Dram => dram.load(dst_off, &buf)?,
            _ => bail!("uDMA bad dst region"),
        }
        self.inflight = None;
        self.done_count += 1;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::dram::DramConfig;

    fn setup() -> (Udma, Dram, Sram, Sram, Sram) {
        (
            Udma::new(),
            Dram::new(DramConfig::default(), 1 << 20),
            Sram::new("fm", layout::FM_SIZE),
            Sram::new("wt", layout::WT_SIZE),
            Sram::new("dmem", layout::DMEM_SIZE),
        )
    }

    #[test]
    fn dram_to_wt_transfer() {
        let (mut u, mut d, mut fm, mut wt, mut dm) = setup();
        d.load(0, &[1, 2, 3, 4, 5, 6, 7, 8]).unwrap();
        u.src = layout::DRAM_BASE;
        u.dst = layout::WT_BASE;
        u.len = 8;
        let done = u.start(0, &mut d).unwrap();
        assert!(u.busy(0));
        assert!(!u.busy(done));
        u.complete(done, &mut d, &mut fm, &mut wt, &mut dm).unwrap();
        assert_eq!(&wt.bytes()[..8], &[1, 2, 3, 4, 5, 6, 7, 8]);
        assert!(u.inflight.is_none());
    }

    #[test]
    fn busy_start_enqueues_descriptor_chain() {
        let (mut u, mut d, mut fm, mut wt, mut dm) = setup();
        d.load(0, &[0xAA; 16]).unwrap();
        u.src = layout::DRAM_BASE;
        u.dst = layout::WT_BASE;
        u.len = 8;
        let done1 = u.start(0, &mut d).unwrap();
        // Second start while busy: queued, not an error.
        u.src = layout::DRAM_BASE + 8;
        u.dst = layout::WT_BASE + 8;
        u.len = 8;
        assert_eq!(u.start(1, &mut d).unwrap(), 0);
        assert_eq!(u.queue.len(), 1);
        assert!(u.busy(done1)); // chain still pending at first finish
        // Drive completion: first transfer lands, chain launches second.
        u.complete(done1, &mut d, &mut fm, &mut wt, &mut dm).unwrap();
        assert_eq!(u.done_count, 1);
        let done2 = u.inflight.unwrap().done_at;
        u.complete(done2, &mut d, &mut fm, &mut wt, &mut dm).unwrap();
        assert_eq!(u.done_count, 2);
        assert!(!u.busy(done2 + 1));
        assert_eq!(&wt.bytes()[..16], &[0xAA; 16]);
    }

    #[test]
    fn queue_overflow_is_error() {
        let (mut u, mut d, ..) = setup();
        u.src = layout::DRAM_BASE;
        u.dst = layout::WT_BASE;
        u.len = 1 << 20; // long transfer keeps the engine busy
        u.start(0, &mut d).unwrap();
        u.len = 4;
        for _ in 0..QUEUE_DEPTH {
            u.start(0, &mut d).unwrap();
        }
        assert!(u.start(0, &mut d).is_err());
    }

    #[test]
    fn rejects_bad_endpoints() {
        let (mut u, mut d, ..) = setup();
        u.src = layout::WT_BASE; // on-chip -> on-chip unsupported
        u.dst = layout::FM_BASE;
        u.len = 4;
        assert!(u.start(0, &mut d).is_err());
    }

    #[test]
    fn fm_spill_roundtrip() {
        let (mut u, mut d, mut fm, mut wt, mut dm) = setup();
        fm.load(0, &[9, 8, 7, 6]).unwrap();
        u.src = layout::FM_BASE;
        u.dst = layout::DRAM_BASE + 0x100;
        u.len = 4;
        let done = u.start(0, &mut d).unwrap();
        u.complete(done, &mut d, &mut fm, &mut wt, &mut dm).unwrap();
        assert_eq!(d.slice(0x100, 4).unwrap(), &[9, 8, 7, 6]);
    }

    #[test]
    fn transfer_time_scales_with_len() {
        let (mut u, mut d, ..) = setup();
        u.src = layout::DRAM_BASE;
        u.dst = layout::WT_BASE;
        u.len = 64;
        let t1 = u.start(0, &mut d).unwrap();
        u.inflight = None;
        u.len = 32 * 1024;
        let t2 = u.start(0, &mut d).unwrap();
        assert!(t2 > t1 * 10);
    }
}
