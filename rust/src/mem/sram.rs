//! Single-cycle on-chip SRAM banks with access accounting (for the energy
//! model) and word/halfword/byte access.

use anyhow::{bail, Result};

/// An on-chip SRAM bank. Accesses are single-cycle (the paper's CIM
/// instructions read FM SRAM and write results in the same cycle).
#[derive(Debug, Clone)]
pub struct Sram {
    name: &'static str,
    data: Vec<u8>,
    /// Read/write word-access counters (energy accounting).
    pub reads: u64,
    pub writes: u64,
}

impl Sram {
    pub fn new(name: &'static str, size: u32) -> Self {
        Sram { name, data: vec![0; size as usize], reads: 0, writes: 0 }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    fn check(&self, offset: u32, width: u32) -> Result<usize> {
        let end = offset as usize + width as usize;
        if end > self.data.len() {
            bail!(
                "{}: access at {:#x}+{} out of bounds (size {:#x})",
                self.name,
                offset,
                width,
                self.data.len()
            );
        }
        Ok(offset as usize)
    }

    pub fn read_u8(&mut self, offset: u32) -> Result<u8> {
        let i = self.check(offset, 1)?;
        self.reads += 1;
        Ok(self.data[i])
    }

    pub fn read_u16(&mut self, offset: u32) -> Result<u16> {
        let i = self.check(offset, 2)?;
        self.reads += 1;
        Ok(u16::from_le_bytes([self.data[i], self.data[i + 1]]))
    }

    pub fn read_u32(&mut self, offset: u32) -> Result<u32> {
        let i = self.check(offset, 4)?;
        self.reads += 1;
        Ok(u32::from_le_bytes([
            self.data[i],
            self.data[i + 1],
            self.data[i + 2],
            self.data[i + 3],
        ]))
    }

    /// Read without bumping the access counters (host/debug access).
    pub fn peek_u32(&self, offset: u32) -> Result<u32> {
        let i = self.check(offset, 4)?;
        Ok(u32::from_le_bytes([
            self.data[i],
            self.data[i + 1],
            self.data[i + 2],
            self.data[i + 3],
        ]))
    }

    pub fn write_u8(&mut self, offset: u32, v: u8) -> Result<()> {
        let i = self.check(offset, 1)?;
        self.writes += 1;
        self.data[i] = v;
        Ok(())
    }

    pub fn write_u16(&mut self, offset: u32, v: u16) -> Result<()> {
        let i = self.check(offset, 2)?;
        self.writes += 1;
        self.data[i..i + 2].copy_from_slice(&v.to_le_bytes());
        Ok(())
    }

    pub fn write_u32(&mut self, offset: u32, v: u32) -> Result<()> {
        let i = self.check(offset, 4)?;
        self.writes += 1;
        self.data[i..i + 4].copy_from_slice(&v.to_le_bytes());
        Ok(())
    }

    /// Write without bumping counters (host-side initialization).
    pub fn poke_u32(&mut self, offset: u32, v: u32) -> Result<()> {
        let i = self.check(offset, 4)?;
        self.data[i..i + 4].copy_from_slice(&v.to_le_bytes());
        Ok(())
    }

    /// Bulk host-side load (program/weight images).
    pub fn load(&mut self, offset: u32, bytes: &[u8]) -> Result<()> {
        let i = self.check(offset, bytes.len() as u32)?;
        self.data[i..i + bytes.len()].copy_from_slice(bytes);
        Ok(())
    }

    /// Raw view (host-side result extraction).
    pub fn bytes(&self) -> &[u8] {
        &self.data
    }

    pub fn reset_counters(&mut self) {
        self.reads = 0;
        self.writes = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rw_roundtrip_widths() {
        let mut s = Sram::new("t", 64);
        s.write_u32(0, 0xDEAD_BEEF).unwrap();
        assert_eq!(s.read_u32(0).unwrap(), 0xDEAD_BEEF);
        assert_eq!(s.read_u16(0).unwrap(), 0xBEEF);
        assert_eq!(s.read_u8(3).unwrap(), 0xDE);
        s.write_u8(1, 0x00).unwrap();
        assert_eq!(s.read_u32(0).unwrap(), 0xDEAD_00EF);
    }

    #[test]
    fn bounds_checked() {
        let mut s = Sram::new("t", 8);
        assert!(s.read_u32(5).is_err());
        assert!(s.write_u32(8, 0).is_err());
        assert!(s.read_u8(7).is_ok());
    }

    #[test]
    fn counters_track_accesses() {
        let mut s = Sram::new("t", 16);
        s.write_u32(0, 1).unwrap();
        s.read_u32(0).unwrap();
        s.read_u32(4).unwrap();
        s.peek_u32(0).unwrap(); // peek doesn't count
        assert_eq!((s.reads, s.writes), (2, 1));
    }

    #[test]
    fn little_endian() {
        let mut s = Sram::new("t", 8);
        s.load(0, &[0x78, 0x56, 0x34, 0x12]).unwrap();
        assert_eq!(s.read_u32(0).unwrap(), 0x1234_5678);
    }
}
