//! DDR4-like DRAM timing model (the paper simulates DRAM access latency
//! "based on DDR4 DRAM [11]" — Ramulator; we model the first-order terms
//! that matter at a 50 MHz core clock: request latency, row activate /
//! precharge, and a bandwidth-limited data bus).
//!
//! All times are in *core* cycles (50 MHz -> 20 ns per cycle). An edge SoC
//! reaches DRAM through a narrow bridge, so the effective bandwidth seen by
//! the core/uDMA is a few bytes per core cycle — this is exactly the
//! bottleneck the paper's weight fusion hides.

use anyhow::Result;

/// Timing parameters (core cycles @ 50 MHz).
#[derive(Debug, Clone)]
pub struct DramConfig {
    /// Fixed request overhead (controller + PHY round trip).
    pub t_req: u64,
    /// Row activate (tRCD) when the row buffer misses.
    pub t_rcd: u64,
    /// Precharge (tRP) when a different row is open.
    pub t_rp: u64,
    /// CAS latency.
    pub t_cas: u64,
    /// Data-bus bytes per core cycle (narrow edge-device bridge).
    pub bytes_per_cycle: u64,
    /// Row size in bytes (row-buffer hit window).
    pub row_bytes: u64,
    /// Number of banks.
    pub banks: usize,
}

impl Default for DramConfig {
    fn default() -> Self {
        // DDR4-2400 x16 behind a narrow 50 MHz edge-SoC bridge:
        //   tRCD = tRP = CL = 14.16 ns  -> 1 core cycle each (rounded up)
        //   request overhead ~ 6 core cycles (controller + APB bridge)
        //   sustained effective bandwidth 1 B / core cycle (50 MB/s): the
        //   bridge serialises beats, so the SoC sees a fraction of the
        //   device bandwidth. Chosen so DRAM weight loading dominates the
        //   un-fused baseline — the regime the paper's §III-A describes
        //   (weight transfer = the largest latency component).
        DramConfig {
            t_req: 6,
            t_rcd: 1,
            t_rp: 1,
            t_cas: 1,
            bytes_per_cycle: 1,
            row_bytes: 2048,
            banks: 8,
        }
    }
}

/// DRAM device + contents + timing state.
#[derive(Debug, Clone)]
pub struct Dram {
    cfg: DramConfig,
    data: Vec<u8>,
    /// Open row per bank (row index), None = all precharged.
    open_row: Vec<Option<u64>>,
    /// Stats.
    pub accesses: u64,
    pub row_hits: u64,
    pub row_misses: u64,
    pub bytes_transferred: u64,
    pub busy_cycles: u64,
}

impl Dram {
    pub fn new(cfg: DramConfig, size: u32) -> Self {
        let banks = cfg.banks;
        Dram {
            cfg,
            data: vec![0; size as usize],
            open_row: vec![None; banks],
            accesses: 0,
            row_hits: 0,
            row_misses: 0,
            bytes_transferred: 0,
            busy_cycles: 0,
        }
    }

    pub fn config(&self) -> &DramConfig {
        &self.cfg
    }

    fn bank_row(&self, addr: u32) -> (usize, u64) {
        let row = addr as u64 / self.cfg.row_bytes;
        (row as usize % self.cfg.banks, row / self.cfg.banks as u64)
    }

    /// Latency (cycles) of a burst of `len` bytes starting at `addr`,
    /// updating row-buffer state. This is the single timing primitive the
    /// CPU (scalar access) and the uDMA (bulk streaming) both use.
    pub fn access_latency(&mut self, addr: u32, len: u32) -> u64 {
        self.accesses += 1;
        self.bytes_transferred += len as u64;
        let mut cycles = self.cfg.t_req + self.cfg.t_cas;
        // Walk the row spans the burst touches.
        let mut cur = addr as u64;
        let end = addr as u64 + len as u64;
        while cur < end {
            let (bank, row) = self.bank_row(cur as u32);
            match self.open_row[bank] {
                Some(r) if r == row => self.row_hits += 1,
                Some(_) => {
                    self.row_misses += 1;
                    cycles += self.cfg.t_rp + self.cfg.t_rcd;
                    self.open_row[bank] = Some(row);
                }
                None => {
                    self.row_misses += 1;
                    cycles += self.cfg.t_rcd;
                    self.open_row[bank] = Some(row);
                }
            }
            let row_end = (cur / self.cfg.row_bytes + 1) * self.cfg.row_bytes;
            cur = row_end.min(end);
        }
        cycles += (len as u64).div_ceil(self.cfg.bytes_per_cycle);
        self.busy_cycles += cycles;
        cycles
    }

    pub fn read_u32(&self, offset: u32) -> Result<u32> {
        let i = offset as usize;
        anyhow::ensure!(i + 4 <= self.data.len(), "DRAM read OOB at {offset:#x}");
        Ok(u32::from_le_bytes([
            self.data[i],
            self.data[i + 1],
            self.data[i + 2],
            self.data[i + 3],
        ]))
    }

    pub fn write_u32(&mut self, offset: u32, v: u32) -> Result<()> {
        let i = offset as usize;
        anyhow::ensure!(i + 4 <= self.data.len(), "DRAM write OOB at {offset:#x}");
        self.data[i..i + 4].copy_from_slice(&v.to_le_bytes());
        Ok(())
    }

    pub fn read_u8(&self, offset: u32) -> Result<u8> {
        self.data
            .get(offset as usize)
            .copied()
            .ok_or_else(|| anyhow::anyhow!("DRAM read OOB at {offset:#x}"))
    }

    pub fn write_u8(&mut self, offset: u32, v: u8) -> Result<()> {
        let i = offset as usize;
        anyhow::ensure!(i < self.data.len(), "DRAM write OOB at {offset:#x}");
        self.data[i] = v;
        Ok(())
    }

    /// Host-side bulk load (weights/audio staged in DRAM before boot).
    pub fn load(&mut self, offset: u32, bytes: &[u8]) -> Result<()> {
        let i = offset as usize;
        anyhow::ensure!(i + bytes.len() <= self.data.len(), "DRAM load OOB");
        self.data[i..i + bytes.len()].copy_from_slice(bytes);
        Ok(())
    }

    pub fn slice(&self, offset: u32, len: u32) -> Result<&[u8]> {
        let i = offset as usize;
        anyhow::ensure!(i + len as usize <= self.data.len(), "DRAM slice OOB");
        Ok(&self.data[i..i + len as usize])
    }

    pub fn reset_counters(&mut self) {
        self.accesses = 0;
        self.row_hits = 0;
        self.row_misses = 0;
        self.bytes_transferred = 0;
        self.busy_cycles = 0;
        self.open_row.fill(None);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn row_hit_cheaper_than_miss() {
        let mut d = Dram::new(DramConfig::default(), 1 << 20);
        let miss = d.access_latency(0, 4);
        let hit = d.access_latency(4, 4);
        assert!(hit < miss, "hit {hit} vs miss {miss}");
    }

    #[test]
    fn bandwidth_dominates_large_bursts() {
        let mut d = Dram::new(DramConfig::default(), 1 << 20);
        let cfg = DramConfig::default();
        let lat = d.access_latency(0, 64 * 1024);
        let floor = 64 * 1024 / cfg.bytes_per_cycle;
        assert!(lat >= floor);
        assert!(lat < floor + 1000, "overheads should be small vs streaming");
    }

    #[test]
    fn sequential_same_row_hits() {
        let mut d = Dram::new(DramConfig::default(), 1 << 20);
        d.access_latency(0, 4);
        d.access_latency(8, 4);
        d.access_latency(16, 4);
        assert_eq!(d.row_misses, 1);
        assert_eq!(d.row_hits, 2);
    }

    #[test]
    fn data_roundtrip() {
        let mut d = Dram::new(DramConfig::default(), 4096);
        d.write_u32(100, 0xCAFE_F00D).unwrap();
        assert_eq!(d.read_u32(100).unwrap(), 0xCAFE_F00D);
        assert!(d.read_u32(4094).is_err());
    }

    #[test]
    fn bank_interleave_rows() {
        let d = Dram::new(DramConfig::default(), 1 << 20);
        let (b0, r0) = d.bank_row(0);
        let (b1, _r1) = d.bank_row(2048);
        assert_ne!((b0, r0), (b1, 0), "consecutive rows map to different banks");
    }
}
