"""L1 correctness: Pallas kernels vs the pure-jnp oracles in ref.py.

This is the CORE correctness signal for the compute layer: hypothesis
sweeps shapes/densities and asserts bit-exact agreement (binary values and
integer-valued sums make exact equality the right check, not allclose)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import cim_conv, ref


def _bits(rng, shape, density=0.5):
    return (rng.random(shape) < density).astype(np.float32)


def _weights(rng, shape, ternary=False):
    vals = [-1.0, 0.0, 1.0] if ternary else [-1.0, 1.0]
    return rng.choice(vals, size=shape).astype(np.float32)


shape_strategy = st.tuples(
    st.integers(1, 40),      # batch rows
    st.integers(1, 520),     # wordlines
    st.integers(1, 140),     # sense amps
    st.integers(0, 2**31 - 1),
    st.floats(0.05, 0.95),
)


@settings(max_examples=25, deadline=None)
@given(shape_strategy, st.booleans(), st.booleans())
def test_cim_mac_matches_ref(dims, binarized, ternary):
    b, wl, sa, seed, density = dims
    rng = np.random.default_rng(seed)
    x = _bits(rng, (b, wl), density)
    w = _weights(rng, (wl, sa), ternary)
    got = cim_conv.cim_mac_trimmed(jnp.asarray(x), jnp.asarray(w), binarized=binarized)
    want = (
        ref.ref_cim_mac(jnp.asarray(x), jnp.asarray(w))
        if binarized
        else ref.ref_cim_mac_raw(jnp.asarray(x), jnp.asarray(w))
    )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=15, deadline=None)
@given(
    st.integers(2, 32).map(lambda n: 2 * n),  # even t
    st.integers(1, 96),
    st.integers(1, 64),
    st.sampled_from([1, 3, 5]),
    st.integers(0, 2**31 - 1),
)
def test_conv1d_binary_matches_ref(t, c_in, c_out, k, seed):
    rng = np.random.default_rng(seed)
    x = _bits(rng, (t, c_in))
    w = _weights(rng, (k, c_in, c_out))
    got = cim_conv.conv1d_binary(jnp.asarray(x), jnp.asarray(w))
    want = ref.ref_conv1d_binary(jnp.asarray(x), jnp.asarray(w))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=10, deadline=None)
@given(
    st.integers(2, 24).map(lambda n: 2 * n),
    st.integers(1, 64),
    st.integers(1, 48),
    st.integers(0, 2**31 - 1),
)
def test_conv_pool_pipeline_matches_unfused(t, c_in, c_out, seed):
    """The fused conv+maxpool kernel (Fig. 7 pipeline) must equal the
    unfused conv-then-pool composition exactly."""
    rng = np.random.default_rng(seed)
    x = _bits(rng, (t, c_in))
    w = _weights(rng, (3, c_in, c_out))
    got = cim_conv.conv1d_pool_binary(jnp.asarray(x), jnp.asarray(w))
    want = ref.ref_maxpool1d(ref.ref_conv1d_binary(jnp.asarray(x), jnp.asarray(w)))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_macro_geometry_xmode():
    """Full X-mode tile: 1024 wordlines x 256 sense amps — one macro fire."""
    rng = np.random.default_rng(0)
    x = _bits(rng, (8, ref.X_MODE_WL))
    w = _weights(rng, (ref.X_MODE_WL, ref.X_MODE_SA))
    got = cim_conv.cim_mac_trimmed(jnp.asarray(x), jnp.asarray(w))
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(ref.ref_cim_mac(jnp.asarray(x), jnp.asarray(w)))
    )


def test_macro_geometry_ymode():
    """Y-mode tile: 512 wordlines x 512 sense amps."""
    rng = np.random.default_rng(1)
    x = _bits(rng, (8, ref.Y_MODE_WL))
    w = _weights(rng, (ref.Y_MODE_WL, ref.Y_MODE_SA))
    got = cim_conv.cim_mac_trimmed(jnp.asarray(x), jnp.asarray(w))
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(ref.ref_cim_mac(jnp.asarray(x), jnp.asarray(w)))
    )


def test_binarize_is_strict_threshold():
    """binarize(0) == 0 (strict >): the SA threshold convention shared with
    the Rust macro model; a mismatch here would silently skew everything."""
    s = jnp.asarray([-2.0, -1.0, 0.0, 1.0, 2.0])
    np.testing.assert_array_equal(np.asarray(ref.binarize(s)), [0, 0, 0, 1, 1])


def test_all_zero_and_all_one_inputs():
    rng = np.random.default_rng(3)
    w = _weights(rng, (64, 32))
    zero = jnp.zeros((4, 64))
    one = jnp.ones((4, 64))
    np.testing.assert_array_equal(
        np.asarray(cim_conv.cim_mac_trimmed(zero, jnp.asarray(w))), np.zeros((4, 32))
    )
    want = ref.ref_cim_mac(one, jnp.asarray(w))
    np.testing.assert_array_equal(
        np.asarray(cim_conv.cim_mac_trimmed(one, jnp.asarray(w))), np.asarray(want)
    )


def test_im2col_flattening_order():
    """Tap-major / channel-minor: position p, tap j, channel c lands at
    column j*c_in + c — the exact contract rust/src/cim/weight_map.rs uses."""
    t, c_in, k = 6, 4, 3
    x = jnp.arange(t * c_in, dtype=jnp.float32).reshape(t, c_in)
    cols = cim_conv.im2col(x, k)
    assert cols.shape == (t, k * c_in)
    # Row 2 sees taps at t=1,2,3 (pad=1): tap j corresponds to x[2+j-1].
    for j in range(k):
        np.testing.assert_array_equal(
            np.asarray(cols[2, j * c_in : (j + 1) * c_in]), np.asarray(x[1 + j])
        )
