"""L2 correctness: model topology, preprocessing, quantization, STE."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import data, model
from compile.kernels import ref


@pytest.fixture(scope="module")
def params():
    cfg = model.CONFIG
    p = model.init_params(jax.random.key(0), cfg)
    audio, _ = data.make_dataset(8, seed=7)
    mean, var = data.feature_stats(audio, cfg.t, cfg.c)
    p["bn_mean"] = jnp.asarray(mean)
    p["bn_var"] = jnp.asarray(var)
    return p


@pytest.fixture(scope="module")
def qparams(params):
    return model.quantize_params(params)


@pytest.fixture(scope="module")
def audio():
    a, _ = data.make_dataset(2, seed=3)
    return jnp.asarray(a[0])


def test_config_fits_macro():
    """Every layer must fit one X-mode macro mapping (DESIGN.md §3)."""
    cfg = model.CONFIG
    for k, ci, co in cfg.conv_shapes:
        assert k * ci <= ref.X_MODE_WL, "wordlines overflow"
        assert co <= ref.X_MODE_SA, "sense amps overflow"


def test_config_weight_sram_split():
    """Resident layers fill <=512Kb weight SRAM; streamed layers exist —
    the premise of the weight-fusion experiment (Fig. 9)."""
    cfg = model.CONFIG
    assert cfg.resident_bits <= 512 * 1024
    assert cfg.streamed_bits > 0
    assert cfg.streamed_bits <= 512 * 1024
    # Table II: 7 convs = 5 + (conv, pool, conv)
    assert len(cfg.conv_shapes) == 7 and cfg.fusion_split == 5


def test_forward_shapes(qparams, audio):
    logits = model.forward(qparams, audio, use_pallas=False)
    assert logits.shape == (model.CONFIG.n_classes,)


def test_pallas_and_ref_paths_bit_exact(qparams, audio):
    lp = model.forward(qparams, audio, use_pallas=True)
    lr = model.forward(qparams, audio, use_pallas=False)
    np.testing.assert_array_equal(np.asarray(lp), np.asarray(lr))


def test_quantize_params_is_binary(qparams):
    for i in range(len(model.CONFIG.conv_shapes)):
        w = np.asarray(qparams[f"conv{i}"])
        assert set(np.unique(w)) <= {-1.0, 1.0}


def test_preprocess_output_is_binary(params, audio):
    x = model.preprocess(audio, params)
    assert x.shape == (model.CONFIG.t, model.CONFIG.c)
    assert set(np.unique(np.asarray(x))) <= {0.0, 1.0}


def test_train_step_decreases_loss():
    """A few STE steps on one batch must reduce the loss (gradient sanity)."""
    from compile import train

    cfg = model.CONFIG
    a, l = data.make_dataset(32, seed=11)
    p = model.init_params(jax.random.key(1), cfg)
    mean, var = data.feature_stats(a, cfg.t, cfg.c)
    p["bn_mean"] = jnp.asarray(mean)
    p["bn_var"] = jnp.asarray(var)
    step = jax.jit(lambda p, a, l: jax.value_and_grad(train.loss_fn)(p, a, l, cfg))
    opt = train.adam_init(p)
    a, l = jnp.asarray(a), jnp.asarray(l)
    loss0, _ = step(p, a, l)
    for _ in range(8):
        loss, g = step(p, a, l)
        for k in ("bn_mean", "bn_var"):
            g[k] = jnp.zeros_like(g[k])
        p, opt = train.adam_update(p, g, opt, lr=3e-3)
    loss1, _ = step(p, a, l)
    assert float(loss1) < float(loss0)


def test_ste_gradients_nonzero():
    cfg = model.CONFIG
    a, l = data.make_dataset(4, seed=5)
    p = model.init_params(jax.random.key(2), cfg)
    from compile import train

    _, g = jax.value_and_grad(train.loss_fn)(p, jnp.asarray(a), jnp.asarray(l), cfg)
    total = sum(float(jnp.abs(g[f"conv{i}"]).sum()) for i in range(7))
    assert total > 0.0, "STE must pass gradients to latent weights"


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 11), st.integers(0, 2**31 - 1))
def test_dataset_envelope_determinism(label, seed):
    """Class envelopes are deterministic; utterances vary with the rng."""
    e1 = data.class_envelope(label)
    e2 = data.class_envelope(label)
    np.testing.assert_array_equal(e1, e2)
    rng = np.random.default_rng(seed)
    u = data.make_utterance(label, rng)
    assert u.shape == (data.AUDIO_LEN,) and u.dtype == np.float32


def test_dataset_balanced():
    _, labels = data.make_dataset(120, seed=0)
    counts = np.bincount(labels, minlength=12)
    assert (counts == 10).all()


def test_feature_stats_match_ref():
    """The numpy preprocessing mirror must be bit-identical to the jnp
    reference chain (quantize -> highpass -> frame features)."""
    cfg = model.CONFIG
    a, _ = data.make_dataset(4, seed=9)
    feats_ref = np.stack(
        [
            np.asarray(
                ref.ref_frame_energy(
                    ref.ref_highpass(ref.quantize_audio(jnp.asarray(x))), cfg.t, cfg.c
                )
            )
            for x in a
        ]
    )
    feats_np = data.preprocess_features(a, cfg.t, cfg.c)
    np.testing.assert_array_equal(feats_np, feats_ref)


def test_features_are_integer_valued():
    """Integer-exact preprocessing: every feature is an exact integer (the
    premise of the bit-exact ISS preprocessing and BN threshold folding)."""
    a, _ = data.make_dataset(2, seed=3)
    f = data.preprocess_features(a)
    np.testing.assert_array_equal(f, np.round(f))


def test_bn_fold_matches_float_bn():
    """Folded integer thresholds reproduce the float BN+binarize bits."""
    rng = np.random.default_rng(0)
    a, _ = data.make_dataset(4, seed=5)
    f = data.preprocess_features(a).reshape(-1, 64)
    gamma = rng.normal(size=64).astype(np.float32)
    beta = rng.normal(size=64).astype(np.float32)
    mean, var = data.feature_stats(a)
    bits_float = np.asarray(
        ref.ref_quantize_binary(
            ref.ref_batchnorm(jnp.asarray(f), gamma, beta, mean, var)
        )
    )
    thr, direction = ref.bn_fold_thresholds(gamma, beta, mean, var)
    fi = f.astype(np.int64)
    bits_int = np.where(
        direction[None, :] > 0,
        fi > thr[None, :],
        np.where(direction[None, :] < 0, fi < thr[None, :] + 1, beta[None, :] > 0),
    ).astype(np.float32)
    np.testing.assert_array_equal(bits_int, bits_float)


def test_maxpool_odd_tail_dropped():
    x = jnp.asarray(np.arange(10, dtype=np.float32).reshape(5, 2))
    out = ref.ref_maxpool1d(x, 2)
    assert out.shape == (2, 2)
    np.testing.assert_array_equal(np.asarray(out), [[2, 3], [6, 7]])


def test_highpass_dc_vs_nyquist_gain():
    """Pre-emphasis: DC gain (|32x-31x| = |x|) is 63x below Nyquist gain
    (alternating signal -> |±63x|)."""
    dc = jnp.full((100,), 100.0)
    y_dc = np.asarray(ref.ref_highpass(dc))
    alt = jnp.asarray([100.0, -100.0] * 50)
    y_alt = np.asarray(ref.ref_highpass(alt))
    assert abs(y_dc[1:]).max() == 100.0          # 32*100 - 31*100
    assert abs(y_alt[1:]).max() == 6300.0        # 32*100 + 31*100
    assert abs(y_alt[1:]).max() / abs(y_dc[1:]).max() == 63.0
