"""Synthetic GSCD-like keyword corpus (DESIGN.md §2 substitution).

The real Google Speech Commands Dataset is not available in this
environment. The accelerator claims we reproduce are *architectural*
(latency/energy), and the accuracy claim only needs a 12-way keyword task
whose difficulty can be tuned; so we synthesize one:

Each of the 12 "keywords" is a deterministic temporal energy envelope — a
class-specific pattern of bursts across the 1-second utterance — carried on
a noisy oscillation, plus per-utterance random phase/amplitude jitter and
additive noise. The model's preprocessing (frame sub-band energies) sees a
class-distinctive (t, c) energy image, exactly the cue real KWS front-ends
exploit, while raw waveforms remain non-trivially separable (noise is tuned
so a well-trained binary CNN lands around the paper's 94 % regime, not 100 %).

The Rust simulator consumes the same corpus through ``artifacts/`` exports,
so golden-model and cycle-model accuracy are computed on identical bits.
"""

from __future__ import annotations

import numpy as np

N_CLASSES = 12
AUDIO_LEN = 16000
_SEED_BASE = 0xC13B


def class_envelope(label: int, t: int = 128) -> np.ndarray:
    """Deterministic per-class burst pattern over ``t`` frames.

    Class k gets a unique on/off pattern derived from a per-class LCG, with
    a guaranteed minimum of 3 bursts so no class is silence."""
    rng = np.random.default_rng(_SEED_BASE + label)
    env = np.zeros(t, dtype=np.float32)
    n_bursts = 3 + label % 4
    for b in range(n_bursts):
        start = int(rng.integers(0, t - 8))
        width = int(rng.integers(6, 24))
        level = 0.5 + 0.5 * float(rng.random())
        env[start : min(t, start + width)] += level
    return np.clip(env, 0.0, 1.5)


def make_utterance(
    label: int, rng: np.random.Generator, *, noise: float = 0.35
) -> np.ndarray:
    """One synthetic 1-second utterance of keyword ``label``."""
    t = 128
    frame = AUDIO_LEN // t
    env = class_envelope(label, t)
    # Per-utterance jitter: amplitude scale, small envelope shift.
    scale = 0.7 + 0.6 * rng.random()
    shift = int(rng.integers(-4, 5))
    env = np.roll(env, shift) * scale
    carrier_freq = 0.15 + 0.02 * (label % 5)
    phase = rng.random() * 2 * np.pi
    n = np.arange(AUDIO_LEN, dtype=np.float32)
    carrier = np.sin(2 * np.pi * carrier_freq * n + phase).astype(np.float32)
    audio = carrier * np.repeat(env, frame).astype(np.float32)
    audio += noise * rng.standard_normal(AUDIO_LEN).astype(np.float32)
    return audio.astype(np.float32)


def make_dataset(
    n: int, seed: int = 0, *, noise: float = 0.35
) -> tuple[np.ndarray, np.ndarray]:
    """(audio (n, 16000) f32, labels (n,) i32), classes balanced round-robin."""
    rng = np.random.default_rng(seed)
    labels = np.arange(n, dtype=np.int32) % N_CLASSES
    rng.shuffle(labels)
    audio = np.stack([make_utterance(int(l), rng, noise=noise) for l in labels])
    return audio, labels


def preprocess_features(audio: np.ndarray, t: int = 128, c: int = 64):
    """numpy mirror of the integer preprocessing front-end in
    ``ref.quantize_audio`` + ``ref_highpass`` + ``ref_frame_energy``:
    ADC quantize, y = 32x - 31x_prev, feature = |y[t*frame + ch]|.
    audio: (n, samples) float. Returns integer-valued (n, t, c) f32."""
    q = np.round(np.clip(audio, -1.0, 1.0) * 2048.0)
    prev = np.concatenate([np.zeros_like(q[:, :1]), q[:, :-1]], axis=1)
    y = 32.0 * q - 31.0 * prev
    frame = audio.shape[-1] // t
    x = y[:, : t * frame].reshape(-1, t, frame)
    return np.abs(x[:, :, :c]).astype(np.float32)


def feature_stats(audio: np.ndarray, t: int = 128, c: int = 64):
    """Per-channel running stats for the preprocessing BN, computed on the
    exact features inference will see."""
    flat = preprocess_features(audio, t, c).reshape(-1, c)
    return flat.mean(axis=0).astype(np.float32), flat.var(axis=0).astype(np.float32)
