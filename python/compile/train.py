"""Train the binary KWS model on the synthetic GSCD corpus (STE + Adam).

Build-time only: produces the latent weights that ``aot.py`` quantizes and
exports. Hand-rolled Adam (optax is not in the image). Run:

    cd python && python -m compile.train --steps 400 --out ../artifacts/kws_params.npz

The loss curve and final train/test accuracy are printed and recorded in
EXPERIMENTS.md (§III-A accuracy row).
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import data, model


def cross_entropy(logits, labels):
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.take_along_axis(logp, labels[:, None], axis=-1).mean()


def loss_fn(params, audio, labels, cfg):
    logits = jax.vmap(lambda a: model.forward_train(params, a, cfg))(audio)
    # Logits are fan-in-normalized GAP sums (unit-ish scale); sharpen the
    # softmax a little. The scale folds away under argmax at inference.
    return cross_entropy(logits * 3.0, labels)


def adam_init(params):
    z = jax.tree.map(jnp.zeros_like, params)
    return {"m": z, "v": jax.tree.map(jnp.zeros_like, params), "t": 0}


def adam_update(params, grads, state, lr=1e-3, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
    mhat = jax.tree.map(lambda m: m / (1 - b1**t), m)
    vhat = jax.tree.map(lambda v: v / (1 - b2**t), v)
    new = jax.tree.map(
        lambda p, mh, vh: p - lr * mh / (jnp.sqrt(vh) + eps), params, mhat, vhat
    )
    return new, {"m": m, "v": v, "t": t}


def accuracy(params, audio, labels, cfg, batch=256):
    """Hard-binary (deployment-path) accuracy."""
    qp = model.quantize_params(params, cfg)
    hits = 0
    for i in range(0, len(labels), batch):
        logits = model.predict(qp, jnp.asarray(audio[i : i + batch]), cfg)
        hits += int((jnp.argmax(logits, -1) == labels[i : i + batch]).sum())
    return hits / len(labels)


def train(
    steps: int = 400,
    batch: int = 64,
    lr: float = 1e-3,
    seed: int = 0,
    n_train: int = 1536,
    n_test: int = 384,
    noise: float = 0.35,
    log_every: int = 20,
    cfg: model.KwsConfig = model.CONFIG,
):
    """Returns (params, history dict)."""
    print(f"generating synthetic GSCD: {n_train} train / {n_test} test")
    train_audio, train_labels = data.make_dataset(n_train, seed=seed, noise=noise)
    test_audio, test_labels = data.make_dataset(n_test, seed=seed + 1, noise=noise)

    params = model.init_params(jax.random.key(seed), cfg)
    mean, var = data.feature_stats(train_audio, cfg.t, cfg.c)
    params["bn_mean"] = jnp.asarray(mean)
    params["bn_var"] = jnp.asarray(var)

    step_fn = jax.jit(
        lambda p, a, l: jax.value_and_grad(loss_fn)(p, a, l, cfg)
    )
    opt = adam_init(params)
    rng = np.random.default_rng(seed)
    history = {"step": [], "loss": []}
    t0 = time.time()
    for step in range(steps):
        idx = rng.integers(0, n_train, size=batch)
        loss, grads = step_fn(
            params, jnp.asarray(train_audio[idx]), jnp.asarray(train_labels[idx])
        )
        # BN stats are frozen running stats, not trained.
        for k in ("bn_mean", "bn_var"):
            grads[k] = jnp.zeros_like(grads[k])
        params, opt = adam_update(params, grads, opt, lr=lr)
        # BinaryConnect-style latent clipping: keep weights inside the
        # sign_ste pass-through window, or their gradients die and the
        # run diverges (observed: collapse after ~300 steps without this).
        for i in range(len(cfg.conv_shapes)):
            params[f"conv{i}"] = jnp.clip(params[f"conv{i}"], -1.0, 1.0)
        history["step"].append(step)
        history["loss"].append(float(loss))
        if step % log_every == 0 or step == steps - 1:
            print(f"step {step:4d}  loss {float(loss):.4f}  ({time.time()-t0:.1f}s)")
    train_acc = accuracy(params, train_audio[:512], train_labels[:512], cfg)
    test_acc = accuracy(params, test_audio, test_labels, cfg)
    print(f"train acc (hard-binary) {train_acc*100:.2f}%  test acc {test_acc*100:.2f}%")
    history["train_acc"] = train_acc
    history["test_acc"] = test_acc
    return params, history


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--noise", type=float, default=0.35)
    ap.add_argument("--out", default="../artifacts/kws_params.npz")
    ap.add_argument("--history", default="../artifacts/train_history.json")
    args = ap.parse_args()
    params, history = train(
        steps=args.steps, batch=args.batch, lr=args.lr, seed=args.seed,
        noise=args.noise,
    )
    np.savez(args.out, **{k: np.asarray(v) for k, v in params.items()})
    with open(args.history, "w") as f:
        json.dump(history, f)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
