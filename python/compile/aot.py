"""AOT lowering: JAX KWS model -> HLO text + weight/test-vector artifacts.

Emits HLO **text**, NOT ``.serialize()``: jax >= 0.5 emits HloModuleProto
with 64-bit instruction ids which the image's xla_extension 0.5.1 rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Outputs (all under ``artifacts/``):
    model.hlo.txt       full inference: (audio, w0..w6, bn x4) -> (logits,)
    macro.hlo.txt       a single X-mode cim_mac tile: (x, w) -> (out,)
    preprocess.hlo.txt  preprocessing stage only: (audio, bn x4) -> (feats,)
    weights/<p>.bin     f32 little-endian parameter payloads
    testvec/*.bin       sample audio + golden logits for Rust integration
    kws_manifest.json   parameter order/shapes/files — the Rust runtime's
                        source of truth for feeding the HLO executable

Python runs ONCE at build time (``make artifacts``); the Rust binary is
self-contained afterwards.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import data, model
from .kernels import cim_conv, ref

PARAM_ORDER = (
    [f"conv{i}" for i in range(7)]
    + [f"th{i}" for i in range(6)]  # SA reference levels (binarized layers)
    + ["bn_gamma", "bn_beta", "bn_mean", "bn_var"]
)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the interchange format)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def load_or_init_params(params_npz: str | None, cfg: model.KwsConfig):
    """Trained params if available, else deterministic init — `make
    artifacts` must work on a fresh checkout without a training run."""
    if params_npz and os.path.exists(params_npz):
        with np.load(params_npz) as z:
            params = {k: jnp.asarray(z[k]) for k in z.files}
        print(f"loaded trained params from {params_npz}")
        return params, True
    print("no trained params found; using deterministic init")
    params = model.init_params(jax.random.key(0), cfg)
    # Representative BN stats from a tiny calibration batch.
    audio, _ = data.make_dataset(64, seed=7)
    mean, var = data.feature_stats(audio, cfg.t, cfg.c)
    params["bn_mean"] = jnp.asarray(mean)
    params["bn_var"] = jnp.asarray(var)
    return params, False


def lower_model(qparams, cfg: model.KwsConfig) -> str:
    """Lower full inference with every parameter as an HLO parameter, in
    PARAM_ORDER, so Rust can feed freshly-loaded weights."""

    def fn(audio, *flat):
        params = dict(zip(PARAM_ORDER, flat))
        return (model.forward(params, audio, cfg, use_pallas=True),)

    specs = [jax.ShapeDtypeStruct((cfg.audio_len,), jnp.float32)] + [
        jax.ShapeDtypeStruct(qparams[k].shape, jnp.float32) for k in PARAM_ORDER
    ]
    return to_hlo_text(jax.jit(fn).lower(*specs))


def lower_macro(cfg: model.KwsConfig) -> str:
    """One X-mode macro tile (1024 x 256) through the Pallas kernel — the
    unit-level cross-check target for rust/src/cim/."""

    def fn(x, w):
        return (cim_conv.cim_mac(x, w, binarized=True),)

    xs = jax.ShapeDtypeStruct((8, ref.X_MODE_WL), jnp.float32)
    ws = jax.ShapeDtypeStruct((ref.X_MODE_WL, ref.X_MODE_SA), jnp.float32)
    return to_hlo_text(jax.jit(fn).lower(xs, ws))


def lower_preprocess(cfg: model.KwsConfig) -> str:
    """Preprocessing stage only (the RISC-V high-precision path)."""

    def fn(audio, gamma, beta, mean, var):
        return (
            ref.ref_preprocess(audio, gamma, beta, mean, var, t=cfg.t, c=cfg.c),
        )

    a = jax.ShapeDtypeStruct((cfg.audio_len,), jnp.float32)
    v = jax.ShapeDtypeStruct((cfg.c,), jnp.float32)
    return to_hlo_text(jax.jit(fn).lower(a, v, v, v, v))


def export(out_dir: str, params_npz: str | None, n_testvec: int, n_eval: int):
    cfg = model.CONFIG
    os.makedirs(out_dir, exist_ok=True)
    os.makedirs(os.path.join(out_dir, "weights"), exist_ok=True)
    os.makedirs(os.path.join(out_dir, "testvec"), exist_ok=True)

    params, trained = load_or_init_params(params_npz, cfg)
    qparams = model.quantize_params(params, cfg)

    # 1. HLO modules
    for name, text in [
        ("model.hlo.txt", lower_model(qparams, cfg)),
        ("macro.hlo.txt", lower_macro(cfg)),
        ("preprocess.hlo.txt", lower_preprocess(cfg)),
    ]:
        path = os.path.join(out_dir, name)
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")

    # 2. Weight payloads (f32 LE). The Rust simulator re-packs binaries to
    #    bitplanes itself; f32 keeps one canonical on-disk format.
    weight_entries = []
    for k in PARAM_ORDER:
        arr = np.asarray(qparams[k], dtype=np.float32)
        fname = f"weights/{k}.bin"
        arr.tofile(os.path.join(out_dir, fname))
        weight_entries.append({"name": k, "shape": list(arr.shape), "file": fname})

    # 3. Test vectors: audio + golden logits through the *reference* path
    #    (bit-identical to the pallas path; asserted by pytest).
    audio, labels = data.make_dataset(n_testvec, seed=1234)
    logits = np.asarray(model.predict(qparams, jnp.asarray(audio), cfg))
    audio.astype(np.float32).tofile(os.path.join(out_dir, "testvec/audio.bin"))
    logits.astype(np.float32).tofile(os.path.join(out_dir, "testvec/logits.bin"))
    labels.astype(np.int32).tofile(os.path.join(out_dir, "testvec/labels.bin"))

    # 4. A larger eval set for the Rust accuracy experiment (§III-A).
    eval_audio, eval_labels = data.make_dataset(n_eval, seed=4321)
    eval_audio.astype(np.float32).tofile(os.path.join(out_dir, "testvec/eval_audio.bin"))
    eval_labels.astype(np.int32).tofile(os.path.join(out_dir, "testvec/eval_labels.bin"))

    manifest = {
        "trained": trained,
        "param_order": PARAM_ORDER,
        "weights": weight_entries,
        "config": {
            "audio_len": cfg.audio_len,
            "t": cfg.t,
            "c": cfg.c,
            "n_classes": cfg.n_classes,
            "kernel": cfg.kernel,
            "channels": [list(p) for p in cfg.channels],
            "fusion_split": cfg.fusion_split,
        },
        "hlo": {
            "model": "model.hlo.txt",
            "macro": "macro.hlo.txt",
            "preprocess": "preprocess.hlo.txt",
        },
        "testvec": {
            "n": n_testvec,
            "audio": "testvec/audio.bin",
            "logits": "testvec/logits.bin",
            "labels": "testvec/labels.bin",
            "n_eval": n_eval,
            "eval_audio": "testvec/eval_audio.bin",
            "eval_labels": "testvec/eval_labels.bin",
        },
    }
    mpath = os.path.join(out_dir, "kws_manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {mpath}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts/model.hlo.txt",
                    help="kept for Makefile compat; parent dir is used")
    ap.add_argument("--params", default="../artifacts/kws_params.npz")
    ap.add_argument("--n-testvec", type=int, default=16)
    ap.add_argument("--n-eval", type=int, default=96)
    args = ap.parse_args()
    out_dir = os.path.dirname(os.path.abspath(args.out)) or "../artifacts"
    export(out_dir, args.params, args.n_testvec, args.n_eval)


if __name__ == "__main__":
    main()
