"""L1 Pallas kernels: the CIM macro's compute hot-spot.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the silicon macro is an
analog 1024-input MAC with 256 parallel sense amps. On a TPU-shaped target
the wordline axis (inputs) becomes the MXU contraction axis, the
bitline/sense-amp axis becomes the output-lane axis, and the sense-amp
threshold + ReLU becomes an epilogue fused *inside* the kernel so the
binarized activation never leaves VMEM — just as the silicon never drives
full-precision values onto the output bus. X-mode vs Y-mode reconfiguration
is two BlockSpec tilings of the same weight buffer.

All kernels run with ``interpret=True`` (CPU PJRT); real-TPU lowering would
emit a Mosaic custom-call the CPU plugin cannot execute. Correctness is
checked against ``ref.py`` by pytest (hypothesis sweeps shapes).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# VMEM-shaped tile sizes. A (8, 128) f32 output tile plus (8, 256) x and
# (256, 128) w operand tiles is ~132 KiB of VMEM — far under the ~16 MiB
# per-core budget, leaving room for double buffering of the streamed
# wordline blocks. The contraction block of 256 keeps the MXU systolic
# array's K dimension saturated.
BLOCK_B = 8      # batch rows per tile (conv rows in flight)
BLOCK_WL = 256   # wordline (contraction) block
BLOCK_SA = 128   # sense-amp (output lane) block


def _pad_to(x, axis, mult):
    """Zero-pad ``x`` along ``axis`` up to a multiple of ``mult``."""
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _mac_kernel(x_ref, w_ref, th_ref, o_ref, *, binarized: bool, nk: int):
    """Grid = (B tiles, SA tiles, WL tiles); the last axis contracts.

    The output block is revisited across the contraction axis and doubles
    as the accumulator (no HBM round-trip between partial sums); the
    sense-amp compare (``sum > th``, th = programmable SA reference) runs
    as an epilogue in the final contraction step so only {0,1} values ever
    leave the kernel.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    if binarized:
        @pl.when(k == nk - 1)
        def _epilogue():
            o_ref[...] = jnp.where(o_ref[...] > th_ref[...], 1.0, 0.0)


@functools.partial(jax.jit, static_argnames=("binarized",))
def cim_mac(x, w, th=None, *, binarized: bool = True):
    """Pallas CIM macro MAC: ``binarize(x @ w - th)`` (or raw sums).

    x: (b, wl) in {0,1};  w: (wl, sa) in {-1,0,+1};  th: (sa,) integer SA
    reference levels (defaults to 0).  Shapes are padded to tile multiples
    internally; zero padding is exact for this op (padded wordlines
    contribute 0 to every sum, padded lanes are sliced away).
    """
    if th is None:
        th = jnp.zeros((w.shape[1],), jnp.float32)
    x = _pad_to(_pad_to(x.astype(jnp.float32), 0, BLOCK_B), 1, BLOCK_WL)
    w = _pad_to(_pad_to(w.astype(jnp.float32), 0, BLOCK_WL), 1, BLOCK_SA)
    th2 = _pad_to(th.astype(jnp.float32)[None, :], 1, BLOCK_SA)
    (bp, wlp), sap = x.shape, w.shape[1]
    nk = wlp // BLOCK_WL
    out = pl.pallas_call(
        functools.partial(_mac_kernel, binarized=binarized, nk=nk),
        grid=(bp // BLOCK_B, sap // BLOCK_SA, nk),
        in_specs=[
            pl.BlockSpec((BLOCK_B, BLOCK_WL), lambda i, j, k: (i, k)),
            pl.BlockSpec((BLOCK_WL, BLOCK_SA), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, BLOCK_SA), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((BLOCK_B, BLOCK_SA), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bp, sap), jnp.float32),
        interpret=True,
    )(x, w, th2)
    return out


def cim_mac_trimmed(x, w, th=None, *, binarized: bool = True):
    """`cim_mac` with the padding sliced back off (test-facing wrapper)."""
    return cim_mac(x, w, th, binarized=binarized)[: x.shape[0], : w.shape[1]]


def _conv_pool_kernel(cols_ref, w_ref, o_ref, *, nk: int):
    """Fused conv + max-pool tile: the paper's conv/max-pool pipeline.

    cols_ref: (2*BLOCK_B, BLOCK_WL) im2col rows (two conv rows per pooled
    output row); w_ref: (BLOCK_WL, BLOCK_SA). The pooled max runs in VMEM in
    the epilogue, so the un-pooled activations never reach HBM — the Pallas
    rendering of Fig. 7 (pooling overlapped with convolution, no
    intermediate store).
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros((2 * BLOCK_B, BLOCK_SA), jnp.float32)

    o_ref[...] += jnp.dot(
        cols_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _epilogue():
        a = jnp.where(o_ref[...] > 0, 1.0, 0.0)
        pooled = jnp.maximum(a[0::2, :], a[1::2, :])
        # Broadcast the pooled rows back into the (interleaved) tile; the
        # wrapper reads every second row. Keeps the output block shape
        # static across grid steps.
        o_ref[...] = jnp.repeat(pooled, 2, axis=0)


@jax.jit
def cim_conv_pool(cols, w):
    """Fused binarized MAC + 2:1 max-pool over row pairs.

    cols: (2*n, wl) im2col rows in {0,1};  w: (wl, sa) in {-1,0,+1}.
    Returns (n, sa) pooled binary activations.
    """
    n2 = cols.shape[0]
    assert n2 % 2 == 0, "conv/pool pipeline consumes row pairs"
    sa = w.shape[1]
    cols = _pad_to(_pad_to(cols.astype(jnp.float32), 0, 2 * BLOCK_B), 1, BLOCK_WL)
    w = _pad_to(_pad_to(w.astype(jnp.float32), 0, BLOCK_WL), 1, BLOCK_SA)
    (bp, wlp), sap = cols.shape, w.shape[1]
    nk = wlp // BLOCK_WL
    out = pl.pallas_call(
        functools.partial(_conv_pool_kernel, nk=nk),
        grid=(bp // (2 * BLOCK_B), sap // BLOCK_SA, nk),
        in_specs=[
            pl.BlockSpec((2 * BLOCK_B, BLOCK_WL), lambda i, j, k: (i, k)),
            pl.BlockSpec((BLOCK_WL, BLOCK_SA), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec(
            (2 * BLOCK_B, BLOCK_SA), lambda i, j, k: (i, j)
        ),
        out_shape=jax.ShapeDtypeStruct((bp, sap), jnp.float32),
        interpret=True,
    )(cols, w)
    return out[0 : n2 : 2, :sa]


def im2col(x, k: int):
    """(t, c) -> (t, k*c) tap-major/channel-minor im2col with symmetric
    padding — identical flattening to the Rust weight mapper and ref.py."""
    t, c = x.shape
    pad = (k - 1) // 2
    xp = jnp.pad(x, ((pad, k - 1 - pad), (0, 0)))
    return jnp.stack([xp[i : i + t] for i in range(k)], axis=1).reshape(t, k * c)


def conv1d_binary(x, w, th=None, *, binarized: bool = True):
    """Binary 1-D convolution via the Pallas macro kernel.

    x: (t, c_in) in {0,1};  w: (k, c_in, c_out) in {-1,+1};
    th: (c_out,) SA reference levels (binarized path only).
    """
    t, c_in = x.shape
    k, _, c_out = w.shape
    cols = im2col(x, k)
    out = cim_mac(cols, w.reshape(k * c_in, c_out), th, binarized=binarized)
    return out[:t, :c_out]


def conv1d_pool_binary(x, w):
    """Binary conv + fused 2:1 max-pool (paper Fig. 7 pipeline)."""
    t, c_in = x.shape
    k, _, c_out = w.shape
    cols = im2col(x, k)
    return cim_conv_pool(cols, w.reshape(k * c_in, c_out))[:, :c_out]
