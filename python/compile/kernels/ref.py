"""Pure-jnp correctness oracles for the CIMR-V compute path.

Every Pallas kernel in this package has an oracle here; pytest asserts
``assert_allclose(kernel(...), ref(...))`` over hypothesis-generated shapes.
These functions are also the *semantic definition* shared with the Rust
cycle-level CIM-macro model (``rust/src/cim/``): the Rust simulator must be
bit-exact against them (binary values, integer-valued accumulations, strict
``> 0`` binarization).

Conventions (see DESIGN.md §3):
  * input activations  IA ∈ {0, 1}        (post-ReLU binarized)
  * weights            W  ∈ {-1, +1}      (binary) or {-1, 0, +1} (ternary)
  * MAC sums are integer-valued (exact in f32 far below 2**24)
  * binarize(s) = 1 if s > 0 else 0       (sense-amp threshold + ReLU fused)
"""

from __future__ import annotations

import jax.numpy as jnp

# --- Macro geometry (paper §II-B) -------------------------------------------
# X-mode: 1024 wordlines (inputs) x 256 sense amps (outputs)
# Y-mode:  512 wordlines (inputs) x 512 sense amps (outputs)
X_MODE_WL, X_MODE_SA = 1024, 256
Y_MODE_WL, Y_MODE_SA = 512, 512
MACRO_BITS = 512 * 1024  # 512 Kb array


def binarize(s):
    """Sense-amp output: threshold at zero, ReLU fused (paper §II-B)."""
    return (s > 0).astype(jnp.float32)


def ref_cim_mac(x, w):
    """The macro's analog MAC, functionally: ``binarize(x @ w)``.

    x: (batch, wl)  in {0,1};  w: (wl, sa) in {-1,0,+1}.
    Returns (batch, sa) in {0,1}.
    """
    return binarize(x.astype(jnp.float32) @ w.astype(jnp.float32))


def ref_cim_mac_raw(x, w):
    """Macro MAC without the SA binarization (used by the final conv layer,
    whose raw sums go to the high-precision RISC-V post-processing path)."""
    return x.astype(jnp.float32) @ w.astype(jnp.float32)


def ref_conv1d_binary(x, w, *, binarized: bool = True):
    """Row-wise binary 1-D convolution with symmetric zero padding so the
    time length is preserved — matches the Rust row-wise dataflow.

    x: (t, c_in) in {0,1};  w: (k, c_in, c_out) in {-1,+1}.
    Returns (t, c_out), binarized unless ``binarized=False``.

    Implemented as an explicit im2col so the contraction axis (k*c_in) is
    literally the macro wordline axis — the same flattening order
    (tap-major, channel-minor) the Rust weight mapper uses.
    """
    t, c_in = x.shape
    k, c_in2, c_out = w.shape
    assert c_in == c_in2
    pad = (k - 1) // 2
    xp = jnp.pad(x, ((pad, k - 1 - pad), (0, 0)))
    # im2col: (t, k*c_in), tap-major / channel-minor
    cols = jnp.stack([xp[i : i + t] for i in range(k)], axis=1).reshape(t, k * c_in)
    wf = w.reshape(k * c_in, c_out)
    s = cols.astype(jnp.float32) @ wf.astype(jnp.float32)
    return binarize(s) if binarized else s


def ref_maxpool1d(x, pool: int = 2):
    """Max pooling over time, stride == window. x: (t, c) -> (t//pool, c)."""
    t, c = x.shape
    tt = (t // pool) * pool
    return x[:tt].reshape(t // pool, pool, c).max(axis=1)


def ref_global_avg_pool(x):
    """(t, c) -> (c,) — the high-precision RISC-V post-processing step."""
    return x.mean(axis=0)


def quantize_audio(audio):
    """ADC model: float waveform -> integer-valued samples (11-bit + sign).

    Stored as f32 holding exact integers so the whole preprocessing chain
    below is *exact* in f32 arithmetic — bit-identical between JAX, the
    Rust host reference and the integer-only RISC-V program on the ISS."""
    return jnp.round(jnp.clip(audio, -1.0, 1.0) * 2048.0)


def ref_highpass(audio):
    """Integer pre-emphasis high-pass: y[t] = 32*x[t] - 31*x[t-1].

    alpha = 31/32 = 0.96875 (vs the textbook 0.97): chosen so the filter is
    exact integer arithmetic (values < 2^21, exact in f32) and the ibex-class
    core computes it with shifts — the deployment-grade quantization any
    edge flow applies. ``audio`` must be integer-valued (quantize_audio)."""
    prev = jnp.concatenate([jnp.zeros((1,), audio.dtype), audio[:-1]])
    return 32.0 * audio - 31.0 * prev


def ref_frame_energy(audio, t: int, c: int):
    """Deterministic framing + per-sample magnitude features:
    (samples,) -> (t, c): feature[t, c] = |y[t*frame + c]|.

    With 16000 samples, t=128 frames of 125 samples, the first c=64
    samples of each frame feed the 64 feature channels. Integer-exact and
    strided-reshape only, so it lowers to trivial HLO and has an exact
    Rust/ISS mirror."""
    n = audio.shape[0]
    frame = n // t
    x = audio[: t * frame].reshape(t, frame)
    return jnp.abs(x[:, :c])


def ref_batchnorm(x, gamma, beta, mean, var, eps: float = 1e-5):
    """Inference-time BN with running stats. x: (t, c)."""
    return gamma * (x - mean) / jnp.sqrt(var + eps) + beta


def ref_quantize_binary(x):
    """Preprocessing quantizer: BN output -> {0,1} activations."""
    return (x > 0).astype(jnp.float32)


def ref_preprocess(audio, gamma, beta, mean, var, *, t: int, c: int):
    """Full paper Table-II preprocessing: quantize (ADC), high-pass,
    features, BN, binarize. ``audio`` is the raw float waveform."""
    filtered = ref_highpass(quantize_audio(audio))
    feats = ref_frame_energy(filtered, t, c)
    return ref_quantize_binary(ref_batchnorm(feats, gamma, beta, mean, var))


def bn_fold_thresholds(gamma, beta, mean, var, eps: float = 1e-5):
    """Fold inference BN + binarize into per-channel integer compares.

    bit = gamma*(f-mean)/std + beta > 0  with integer features f is
      gamma > 0:  f >  tau   where tau = mean - beta*std/gamma
      gamma < 0:  f <  tau
      gamma = 0:  bit = (beta > 0) constant
    Returns (int_threshold floor(tau), direction) per channel, the exact
    integer comparison the RISC-V program performs: `f > floor(tau)` is
    equivalent to `f > tau` for integer f when tau is not an integer;
    ties are broken identically because floor is computed in f64 here."""
    import numpy as np

    g = np.asarray(gamma, np.float64)
    b = np.asarray(beta, np.float64)
    m = np.asarray(mean, np.float64)
    s = np.sqrt(np.asarray(var, np.float64) + eps)
    tau = m - b * s / np.where(g == 0, 1.0, g)
    thr = np.floor(tau).astype(np.int64)
    direction = np.sign(g).astype(np.int64)  # +1: f>tau, -1: f<tau, 0: const
    return thr, direction
