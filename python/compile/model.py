"""L2: the CIMR-V keyword-spotting model (paper Table II) in JAX.

Topology (Table II):
    preprocessing        high-pass filter, sub-band energy features, BN,
                         quantize to {0,1}            (RISC-V, high precision)
    convolution in CIM   (binary conv1d k=3 + max-pool 2:1) x 5
    weight fusion        weight update (layers 6-7 streamed from DRAM while
                         layers 1-5 compute; a *scheduling* event — the math
                         here is unchanged)
    convolution in CIM   conv, max-pool, conv (final conv emits raw sums)
    post-processing      global average pooling       (RISC-V, high precision)

Two forward paths share one set of quantized weights:
  * ``forward``       — inference path, built on the L1 Pallas kernels; this
                        is what ``aot.py`` lowers to HLO for the Rust runtime
                        (the bit-exact golden model for the cycle simulator).
  * ``forward_train`` — straight-through-estimator path for training the
                        binary weights (pure jnp; never shipped).

The channel plan keeps every layer inside one X-mode mapping of the macro
(k*c_in <= 1024 wordlines, c_out <= 256 sense amps) and makes layers 1-5
(372 Kb) fill the 512 Kb weight SRAM while layers 6-7 (201 Kb) must be
streamed — which is exactly what makes weight fusion worth measuring.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .kernels import cim_conv, ref


@dataclasses.dataclass(frozen=True)
class KwsConfig:
    """Dimensions of the keyword-spotting model (paper Table II + §III-A)."""

    audio_len: int = 16000        # 1 s @ 16 kHz
    t: int = 128                  # frames
    c: int = 64                   # feature channels
    n_classes: int = 12           # GSCD 12-way
    kernel: int = 3
    # (c_in, c_out) per conv layer; pool follows layers 0-4 and 5.
    # Sized so every layer fits one X-mode macro mapping (k*c_in <= 1024,
    # c_out <= 256) AND the full weight-stream set (signs + thresholds,
    # ~45 KiB) fits the 512 Kb weight SRAM — the premise of the weight
    # fusion flow (all DRAM traffic prefetched behind compute, Fig. 8).
    channels: tuple = ((64, 64), (64, 128), (128, 128), (128, 256),
                       (256, 128), (128, 128), (128, 12))
    fusion_split: int = 5         # layers [0,5) resident; [5,7) weight-fused

    @property
    def conv_shapes(self):
        return [(self.kernel, ci, co) for ci, co in self.channels]

    def weight_bits(self, layer: int) -> int:
        k, ci, co = self.conv_shapes[layer]
        return k * ci * co

    @property
    def resident_bits(self) -> int:
        return sum(self.weight_bits(i) for i in range(self.fusion_split))

    @property
    def streamed_bits(self) -> int:
        return sum(
            self.weight_bits(i)
            for i in range(self.fusion_split, len(self.channels))
        )


CONFIG = KwsConfig()


def init_params(key, cfg: KwsConfig = CONFIG):
    """Latent float parameters (binarized by sign() in both forward paths).

    ``th{i}`` are per-output-channel sense-amp reference levels for the
    binarized layers 0..n-2: the macro [7] this chip integrates exposes a
    configurable SA reference, and folding the (digital) BN affine into
    that threshold is the standard BNN deployment trick — at inference the
    comparison is ``sum > th`` with an *integer* th (see quantize_params).
    The final raw-sum layer has no threshold (its sums go to the RISC-V
    GAP at full precision)."""
    params = {}
    for i, (k, ci, co) in enumerate(cfg.conv_shapes):
        key, sub = jax.random.split(key)
        params[f"conv{i}"] = jax.random.normal(sub, (k, ci, co)) * 0.1
        if i < len(cfg.conv_shapes) - 1:
            params[f"th{i}"] = jnp.zeros((co,))
    params["bn_gamma"] = jnp.ones((cfg.c,))
    params["bn_beta"] = jnp.zeros((cfg.c,))
    params["bn_mean"] = jnp.zeros((cfg.c,))
    params["bn_var"] = jnp.ones((cfg.c,))
    return params


def quantize_params(params, cfg: KwsConfig = CONFIG):
    """Latent floats -> what the chip actually holds: binary {-1,+1}
    weights and *integer* SA thresholds (binary-MAC sums are integers, so
    an integer reference loses nothing after rounding).

    BN running stats stay float (preprocessing runs on the RISC-V core at
    high precision, per Fig. 10)."""
    out = dict(params)
    for i in range(len(cfg.conv_shapes)):
        out[f"conv{i}"] = jnp.where(params[f"conv{i}"] >= 0, 1.0, -1.0)
        if f"th{i}" in params:
            # Latent thresholds live in fan-in-normalized units (the
            # training path compares s/sqrt(n) > th~); the silicon compares
            # raw integer sums, so map back: th = round(th~ * sqrt(n)).
            k, ci, _ = cfg.conv_shapes[i]
            out[f"th{i}"] = jnp.round(params[f"th{i}"] * jnp.sqrt(float(k * ci)))
    return out


# --- Straight-through estimators (training only) -----------------------------

@jax.custom_vjp
def sign_ste(w):
    return jnp.where(w >= 0, 1.0, -1.0)


def _sign_fwd(w):
    return sign_ste(w), w


def _sign_bwd(w, g):
    # Clipped straight-through: pass gradient where |w| <= 1.
    return (g * (jnp.abs(w) <= 1.0),)


sign_ste.defvjp(_sign_fwd, _sign_bwd)


@jax.custom_vjp
def binarize_ste(x):
    return (x > 0).astype(jnp.float32)


def _bin_fwd(x):
    return binarize_ste(x), x


def _bin_bwd(x, g):
    # Hard-sigmoid surrogate window. The training path feeds this
    # *fan-in-normalized* pre-activations (unit-ish variance), so the
    # classic |x| <= 1 window is correctly scaled.
    return (g * (jnp.abs(x) <= 1.0),)


binarize_ste.defvjp(_bin_fwd, _bin_bwd)


# --- Forward paths -----------------------------------------------------------

def preprocess(audio, params, cfg: KwsConfig = CONFIG):
    """RISC-V preprocessing stage (high precision)."""
    return ref.ref_preprocess(
        audio, params["bn_gamma"], params["bn_beta"], params["bn_mean"],
        params["bn_var"], t=cfg.t, c=cfg.c,
    )


def _conv_stack(x, weights, thresholds, cfg: KwsConfig, conv, pool):
    """Shared layer schedule: 5x(conv+pool), [weight fusion], conv, pool,
    conv(raw). ``conv``/``pool`` are injected so the train / Pallas /
    reference paths share one definition of the topology."""
    n = len(cfg.conv_shapes)
    for i in range(n - 1):
        # (layers >= fusion_split were streamed in by weight fusion; a
        # scheduling event only — the math is identical)
        x = pool(conv(x, weights[i], thresholds[i]))
    x = conv(x, weights[n - 1], None)  # raw sums for the RISC-V GAP
    return x


def forward(params, audio, cfg: KwsConfig = CONFIG, *, use_pallas: bool = True):
    """Inference with hard-binary weights/activations.

    ``params`` must already be quantized (see ``quantize_params``); this is
    the function AOT-lowered for the Rust golden runtime. Returns the
    (n_classes,) raw logits produced by the RISC-V global average pooling.
    """
    x = preprocess(audio, params, cfg)
    n = len(cfg.conv_shapes)
    weights = [params[f"conv{i}"] for i in range(n)]
    thresholds = [params[f"th{i}"] for i in range(n - 1)] + [None]
    if use_pallas:
        def conv(x, w, th):
            # threshold fused in the kernel epilogue (SA reference compare)
            return cim_conv.conv1d_binary(x, w, th, binarized=th is not None)
    else:
        def conv(x, w, th):
            s = ref.ref_conv1d_binary(x, w, binarized=False)
            return s if th is None else ref.binarize(s - th)

    x = _conv_stack(x, weights, thresholds, cfg, conv, ref.ref_maxpool1d)
    return ref.ref_global_avg_pool(x)


def forward_train(params, audio, cfg: KwsConfig = CONFIG):
    """Training path: latent float params, STE through both quantizers.

    Pre-activations are normalized by sqrt(fan-in) so they are unit-ish
    variance at every depth — the standard way to keep a deep BNN
    trainable without inter-layer BN (which the silicon doesn't have).
    The normalization commutes with the hard compare, so inference still
    uses raw integer sums (see quantize_params)."""
    x = preprocess(audio, params, cfg)
    n = len(cfg.conv_shapes)

    def conv(x, w, th):
        s = ref.ref_conv1d_binary(x, sign_ste(w), binarized=False)
        z = s / jnp.sqrt(float(w.shape[0] * w.shape[1]))
        return z if th is None else binarize_ste(z - th)

    weights = [params[f"conv{i}"] for i in range(n)]
    thresholds = [params[f"th{i}"] for i in range(n - 1)] + [None]
    x = _conv_stack(x, weights, thresholds, cfg, conv, ref.ref_maxpool1d)
    return ref.ref_global_avg_pool(x)


def predict(params, audio_batch, cfg: KwsConfig = CONFIG):
    """Batched hard-binary inference (reference path; fast on CPU)."""
    return jax.vmap(lambda a: forward(params, a, cfg, use_pallas=False))(
        audio_batch
    )
