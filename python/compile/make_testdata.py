"""Generate the checked-in tiny pre-trained artifact set (rust/testdata).

The Rust integration / golden-crosscheck suites need a trained model +
golden logits to run; a full ``make artifacts`` export is megabytes and
needs this Python environment. This script trains a *small* Table-II-
shaped model (7 conv layers, 64 channels, fusion_split 5) on the synthetic
GSCD corpus and exports a compact artifact set the Rust loaders understand
natively:

* ``weights/conv{i}.bin`` — packed sign bits (bit = +1), flat [k][ci][co]
  order, LSB-first u32 little-endian (manifest ``format.weights =
  "sign_bits"``) — 32x smaller than the f32 export.
* ``testvec/*_i16.bin``   — audio as quantized i16 samples ``k`` with
  waveform value ``k/2048`` (exact in f32, so the float pipeline is
  reproduced bit for bit; ``format.audio = "i16"``).
* ``testvec/logits.bin``  — golden logits from the *JAX reference path*
  (an implementation independent of the Rust one).

Before writing anything, every exported utterance is verified through an
integer-only numpy mirror of the Rust host reference (folded-BN compares,
integer conv sums, OR-pooling, f32 GAP division): its logits must equal
the JAX float path bit for bit, which is exactly the contract the Rust
suites then re-check.

Eval utterances keep their true corpus labels; utterances the trained
model misclassifies are skipped so the accuracy regression test pins the
trained operating point (the set is a regression anchor, not a benchmark).

Run from ``python/``:  python -m compile.make_testdata
"""

from __future__ import annotations

import json
import os
import struct

import jax
import numpy as np

from . import data, model, train
from .kernels import ref

OUT = os.path.join(os.path.dirname(__file__), "..", "..", "rust", "testdata", "artifacts")

CFG = model.KwsConfig(
    channels=((64, 64), (64, 64), (64, 64), (64, 64), (64, 64), (64, 64), (64, 12)),
    fusion_split=5,
)

N_TESTVEC = 3
N_EVAL = 8


# --- integer mirror of the Rust host reference (model/reference.rs) ---------

def int_preprocess(audio_f32: np.ndarray, thr: np.ndarray, dirs: np.ndarray,
                   beta: np.ndarray, t: int, c: int) -> np.ndarray:
    q = np.round(np.clip(audio_f32, -1.0, 1.0) * 2048.0).astype(np.int64)
    frame = audio_f32.shape[0] // t
    idx = (np.arange(t)[:, None] * frame + np.arange(c)[None, :])  # (t, c)
    x = q[idx]
    prev = np.where(idx == 0, 0, q[np.maximum(idx - 1, 0)])
    f = np.abs(32 * x - 31 * prev)
    gt = f > thr[None, :]
    lt = f < (thr[None, :] + 1)
    const = (beta > 0.0)[None, :]
    bits = np.where(dirs[None, :] > 0, gt, np.where(dirs[None, :] < 0, lt, const))
    return bits.astype(np.int64)


def int_conv_sums(x: np.ndarray, w: np.ndarray) -> np.ndarray:
    """x: (t, ci) {0,1}; w: (k, ci, co) {-1,+1} -> integer sums (t, co)."""
    t, ci = x.shape
    k = w.shape[0]
    pad = (k - 1) // 2
    xp = np.pad(x, ((pad, k - 1 - pad), (0, 0)))
    cols = np.stack([xp[i: i + t] for i in range(k)], axis=1).reshape(t, k * ci)
    return cols.astype(np.int64) @ w.reshape(k * ci, -1).astype(np.int64)


def int_infer(audio_f32, qparams, thr, dirs, cfg) -> np.ndarray:
    beta = np.asarray(qparams["bn_beta"], np.float64)
    x = int_preprocess(audio_f32, thr, dirs, beta, cfg.t, cfg.c)
    n = len(cfg.conv_shapes)
    for i in range(n - 1):
        w = np.asarray(qparams[f"conv{i}"], np.int64)
        th = np.asarray(qparams[f"th{i}"], np.int64)
        s = int_conv_sums(x, w)
        x = (s > th[None, :]).astype(np.int64)
        # 2:1 max pool == OR of row pairs for binary maps.
        tt = (x.shape[0] // 2) * 2
        x = x[:tt].reshape(-1, 2, x.shape[1]).max(axis=1)
    s = int_conv_sums(x, np.asarray(qparams[f"conv{n-1}"], np.int64))
    acc = s.sum(axis=0)  # exact integer GAP accumulator
    final_t = np.float32(s.shape[0])
    return (acc.astype(np.float32) / final_t).astype(np.float32)


# --- compact writers ---------------------------------------------------------

def write_f32(path, arr):
    np.asarray(arr, "<f4").tofile(path)


def write_i32(path, arr):
    np.asarray(arr, "<i4").tofile(path)


def pack_sign_bits(w: np.ndarray) -> np.ndarray:
    """±1 weights, flat [k][ci][co] order -> LSB-first u32 words."""
    flat = (np.asarray(w).reshape(-1) > 0).astype(np.uint64)
    n = flat.shape[0]
    words = np.zeros((n + 31) // 32, np.uint64)
    shifts = (np.arange(n, dtype=np.uint64) % np.uint64(32)).astype(np.uint64)
    np.bitwise_or.at(words, np.arange(n) // 32, flat << shifts)
    return words.astype("<u4")


def quantize_i16(audio: np.ndarray) -> np.ndarray:
    return np.round(np.clip(audio, -1.0, 1.0) * 2048.0).astype("<i2")


def main():
    steps = int(os.environ.get("TESTDATA_STEPS", "220"))
    params, history = train.train(
        steps=steps, batch=48, n_train=960, n_test=240, noise=0.35, seed=3, cfg=CFG,
    )
    qp = model.quantize_params(params, CFG)
    thr, dirs = ref.bn_fold_thresholds(
        qp["bn_gamma"], qp["bn_beta"], qp["bn_mean"], qp["bn_var"]
    )

    # Candidate pool from a held-out seed; keep utterances the deployed
    # (hard-binary) model classifies correctly, spread over classes.
    pool_audio, pool_labels = data.make_dataset(96, seed=1234, noise=0.35)
    # Audio is shipped as i16: evaluate on the reconstructed waveform so
    # the exported logits match what the Rust side recomputes.
    pool_audio = quantize_i16(pool_audio).astype(np.float32) / np.float32(2048.0)
    preds = np.argmax(np.asarray(model.predict(qp, pool_audio, CFG)), axis=-1)
    correct = np.nonzero(preds == pool_labels)[0]
    acc = len(correct) / len(pool_labels)
    print(f"candidate-pool accuracy: {100*acc:.1f}% ({len(correct)}/{len(pool_labels)})")
    assert len(correct) >= N_TESTVEC + N_EVAL, "model too weak — train longer"

    # Deterministic selection: first correct index of each class, round
    # robin, until both sets are filled.
    chosen: list[int] = []
    by_class = {k: [i for i in correct if pool_labels[i] == k] for k in range(12)}
    while len(chosen) < N_TESTVEC + N_EVAL:
        for k in range(12):
            if by_class[k] and len(chosen) < N_TESTVEC + N_EVAL:
                chosen.append(by_class[k].pop(0))
    tv_idx, ev_idx = chosen[:N_TESTVEC], chosen[N_TESTVEC:]

    # Golden logits from the JAX float path; verify the integer mirror
    # (the Rust-side semantics) reproduces them bit for bit.
    for i in tv_idx + ev_idx:
        jax_logits = np.asarray(
            model.forward(qp, pool_audio[i], CFG, use_pallas=False), np.float32
        )
        mirror = int_infer(pool_audio[i], qp, thr, dirs, CFG)
        assert np.array_equal(jax_logits, mirror), (
            f"utterance {i}: integer mirror disagrees with JAX float path\n"
            f"jax:    {jax_logits}\nmirror: {mirror}"
        )
    print("integer mirror vs JAX float path: bit-exact on all exported utterances")

    tv_logits = np.stack([
        np.asarray(model.forward(qp, pool_audio[i], CFG, use_pallas=False), np.float32)
        for i in tv_idx
    ])

    # --- write the set -------------------------------------------------------
    wdir = os.path.join(OUT, "weights")
    tdir = os.path.join(OUT, "testvec")
    os.makedirs(wdir, exist_ok=True)
    os.makedirs(tdir, exist_ok=True)

    for i in range(len(CFG.conv_shapes)):
        pack_sign_bits(qp[f"conv{i}"]).tofile(os.path.join(wdir, f"conv{i}.bin"))
        if f"th{i}" in qp:
            write_f32(os.path.join(wdir, f"th{i}.bin"), qp[f"th{i}"])
    for name in ("bn_gamma", "bn_beta", "bn_mean", "bn_var"):
        write_f32(os.path.join(wdir, f"{name}.bin"), qp[name])

    quantize_i16(np.concatenate([pool_audio[i] for i in tv_idx])).tofile(
        os.path.join(tdir, "audio_i16.bin")
    )
    write_i32(os.path.join(tdir, "labels.bin"), [pool_labels[i] for i in tv_idx])
    write_f32(os.path.join(tdir, "logits.bin"), tv_logits.reshape(-1))
    quantize_i16(np.concatenate([pool_audio[i] for i in ev_idx])).tofile(
        os.path.join(tdir, "eval_audio_i16.bin")
    )
    write_i32(os.path.join(tdir, "eval_labels.bin"), [pool_labels[i] for i in ev_idx])

    manifest = {
        "config": {
            "t": CFG.t,
            "c": CFG.c,
            "kernel": CFG.kernel,
            "n_classes": CFG.n_classes,
            "audio_len": CFG.audio_len,
            "fusion_split": CFG.fusion_split,
            "channels": [list(p) for p in CFG.channels],
        },
        "trained": True,
        "format": {"weights": "sign_bits", "audio": "i16"},
        "provenance": "python/compile/make_testdata.py "
                      f"(steps={steps}, test_acc={history['test_acc']:.4f})",
    }
    with open(os.path.join(OUT, "kws_manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    total = sum(
        os.path.getsize(os.path.join(r, f))
        for r, _, fs in os.walk(OUT) for f in fs
    )
    print(f"wrote {OUT} ({total/1024:.0f} KiB, test acc {history['test_acc']*100:.2f}%)")
    # struct is only imported to guarantee the platform is little-endian
    # IEEE-754 — the formats above are explicit ("<f4"/"<i4"/"<u4"/"<i2").
    assert struct.pack("<f", 1.0) == b"\x00\x00\x80\x3f"


if __name__ == "__main__":
    main()
