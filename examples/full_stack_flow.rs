//! The paper's "full stack flow" (Fig. 10), step by step and instrumented:
//! compile the model to the CIM-type ISA, disassemble a window of the
//! program, run it, and show the per-phase latency ladder for every
//! optimization level — the paper's end-to-end inference flow (RISC-V
//! mode / CIM mode / weight-fusion mode) made visible.
//!
//!     make artifacts && cargo run --release --example full_stack_flow

use cimrv::baselines::OptLevel;
use cimrv::compiler::build_kws_program;
use cimrv::isa::{decode, disasm};
use cimrv::mem::dram::DramConfig;
use cimrv::model::{dataset, KwsModel};
use cimrv::sim::Soc;

fn main() -> anyhow::Result<()> {
    let model = KwsModel::load_default()?;
    let audio = dataset::synth_utterance(2, 9, model.audio_len, 0.37);

    // Stage 1: compile (train/quantize happened in python at build time).
    let program = build_kws_program(&model, OptLevel::FULL)?;
    println!("=== compiled program: {} instructions ===", program.imem.len());
    println!("first CIM-type instructions in the stream:");
    let mut shown = 0;
    for (i, w) in program.imem.iter().enumerate() {
        if let Ok(instr) = decode(*w) {
            if matches!(instr, cimrv::isa::Instr::Cim(_)) {
                println!("  [{:#07x}] {}", i * 4, disasm(&instr));
                shown += 1;
                if shown >= 8 {
                    break;
                }
            }
        }
    }

    // Stage 2: deploy + run at each optimization level.
    println!("\n=== per-phase latency by optimization level ===");
    for (name, opt) in OptLevel::ladder() {
        let prog = build_kws_program(&model, opt)?;
        let mut soc = Soc::new(prog, DramConfig::default())?;
        let r = soc.infer(&audio)?;
        println!("{name:<28} {}", r.phases.render());
    }
    Ok(())
}
