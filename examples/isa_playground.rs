//! ISA playground: hand-assemble a tiny RV32IM+CIM routine with the
//! in-tree assembler, run it on a bare SoC, and watch the CIM macro do a
//! MAC — the smallest possible "hello, CIM-type instruction" (Fig. 4).
//!
//!     cargo run --release --example isa_playground

use cimrv::compiler::asm::Asm;
use cimrv::cpu::{Cpu, StepOutcome};
use cimrv::isa::{decode, disasm, CimInstr, Reg};
use cimrv::mem::bus::Bus;
use cimrv::mem::dram::DramConfig;
use cimrv::mem::layout;

fn main() -> anyhow::Result<()> {
    let mut a = Asm::new();
    // Identity-ish weights: port word 0 of column 0 = 0xFFFFFFFF (all +1),
    // mask word likewise; threshold 0; fire on an input with 3 hot bits.
    a.li(Reg::A0, layout::FM_BASE as i64); // FM scratch base
    a.li(Reg::T0, 0xFFFF_FFFFu32 as i64);
    a.sw(Reg::A0, Reg::T0, 0); // ones word in FM[0]
    a.li(Reg::T0, 0b1011); // the input vector (3 hot bits)
    a.sw(Reg::A0, Reg::T0, 4);
    // cim_w: sign plane col 0 word 0 <- ones; mask plane likewise.
    a.li(Reg::A1, 0);
    a.cim(CimInstr::write(Reg::A0, 0, Reg::A1, 0));
    a.li(Reg::A1, cimrv::cim::weight_map::MASK_BASE as i64);
    a.cim(CimInstr::write(Reg::A0, 0, Reg::A1, 0));
    // CIM cfg: X-mode, window = 1 word.
    a.li(Reg::T1, layout::MMIO_BASE as i64);
    a.li(Reg::T0, cimrv::cim::CimConfig { window_words: 1, ..Default::default() }.to_bits() as i64);
    a.sw(Reg::T1, Reg::T0, layout::MMIO_CIM_CFG as i32);
    // cim_conv: shift FM[1] in, fire, store latch word 0 to FM[8].
    a.cim(CimInstr::conv(Reg::A0, 1, Reg::A0, 8, 0, true));
    // Read the raw sum of SA column 0 back to FM[9].
    a.li(Reg::A1, cimrv::cim::weight_map::RAW_BASE as i64);
    a.cim(CimInstr::read(Reg::A1, 0, Reg::A0, 9));
    a.ebreak();

    let words = a.assemble()?;
    println!("=== program ===");
    for (i, w) in words.iter().enumerate() {
        println!("  [{:#06x}] {:08x}  {}", i * 4, w, disasm(&decode(*w)?));
    }

    let mut bus = Bus::new(DramConfig::default());
    for (i, w) in words.iter().enumerate() {
        bus.imem.poke_u32((i * 4) as u32, *w)?;
    }
    let mut cpu = Cpu::new(0);
    let mut now = 0;
    while let StepOutcome::Retired { cycles } = cpu.step(&mut bus)? {
        now += cycles;
        bus.tick(now)?;
    }
    println!("\n=== result ===");
    println!("binarized latch word: {:#x}", bus.fm.peek_u32(32)?);
    println!("raw MAC sum of SA 0: {}", bus.fm.peek_u32(36)? as i32);
    println!("(input had 3 hot bits x weight +1 -> sum 3, 3 > 0 -> latch bit set)");
    assert_eq!(bus.fm.peek_u32(36)? as i32, 3);
    assert_eq!(bus.fm.peek_u32(32)? & 1, 1);
    println!("cycles: {now}, instructions: {}", cpu.stats.instret);
    Ok(())
}
