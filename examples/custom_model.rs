//! Custom model: the library is not hardwired to the paper's KWS network —
//! build an arbitrary binary CNN programmatically, compile it through the
//! same full-stack flow, and validate the simulator against the host
//! reference. (This is the "high programmability of RISC-V" half of the
//! paper's pitch: new models are a compiler invocation, not an RTL spin.)
//!
//!     cargo run --release --example custom_model

use cimrv::baselines::OptLevel;
use cimrv::compiler::build_kws_program;
use cimrv::mem::dram::DramConfig;
use cimrv::model::kws::{fold_bn, LayerSpec};
use cimrv::model::{reference, KwsModel};
use cimrv::sim::Soc;
use cimrv::util::rng::Rng;

/// Build a 4-layer binary CNN with chosen channel widths.
fn build_model(channels: &[(usize, usize)], seed: u64) -> KwsModel {
    let mut rng = Rng::new(seed);
    let c0 = channels[0].0;
    let n = channels.len();
    let layers: Vec<LayerSpec> = channels
        .iter()
        .enumerate()
        .map(|(i, &(ci, co))| {
            let last = i == n - 1;
            LayerSpec {
                c_in: ci,
                c_out: co,
                kernel: 3,
                pooled: !last,
                binarized: !last,
                weights: (0..3 * ci * co).map(|_| rng.pm1()).collect(),
                thresholds: if last {
                    vec![]
                } else {
                    (0..co).map(|_| rng.range(0, 9) as i32 - 4).collect()
                },
            }
        })
        .collect();
    // Plausible BN stats for the integer feature distribution.
    let gamma = vec![1.0; c0];
    let beta = vec![0.4; c0];
    let mean = vec![25_000.0; c0];
    let var = vec![6.0e8; c0];
    let (pre_thr, pre_dir) = fold_bn(&gamma, &beta, &mean, &var);
    KwsModel {
        audio_len: 16000,
        t: 128,
        c: c0,
        n_classes: channels[n - 1].1,
        fusion_split: n - 1,
        layers,
        bn_gamma: gamma,
        bn_beta: beta,
        bn_mean: mean,
        bn_var: var,
        pre_thr,
        pre_dir,
        trained: false,
        artifacts_dir: std::path::PathBuf::new(),
    }
}

fn main() -> anyhow::Result<()> {
    // Three different topologies through the same flow.
    let configs: &[(&str, Vec<(usize, usize)>)] = &[
        ("tiny 3-layer", vec![(32, 32), (32, 64), (64, 10)]),
        ("wide 4-layer", vec![(64, 128), (128, 256), (256, 128), (128, 4)]),
        ("deep 6-layer", vec![(32, 64), (64, 64), (64, 128), (128, 128), (128, 64), (64, 8)]),
    ];
    for (name, channels) in configs {
        let model = build_model(channels, 7);
        let audio = cimrv::model::dataset::synth_utterance(1, 3, model.audio_len, 0.3);
        let prog = build_kws_program(&model, OptLevel::FULL)?;
        let mut soc = Soc::new(prog, DramConfig::default())?;
        let r = soc.infer(&audio)?;
        let want = reference::infer(&model, &audio);
        assert_eq!(r.logits, want, "{name}: ISS must match the reference");
        println!(
            "{name:<14} {} classes | {:>7} cycles ({:.3} ms @50MHz) | {:>6.2} uJ | bit-exact ✓",
            model.n_classes,
            r.cycles,
            1e3 * r.seconds_at_50mhz,
            r.energy.total_uj()
        );
    }
    println!("\nany binary CNN that fits the macro (k*c_in <= 1024, c_out <= 256)\nand the 512Kb weight SRAM compiles and runs through the same flow.");
    Ok(())
}
