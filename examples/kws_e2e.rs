//! End-to-end driver (EXPERIMENTS.md §E2E): serve a batch of synthetic
//! GSCD keywords through the threaded coordinator over the cycle-accurate
//! chip, verify every response against the PJRT golden model (the
//! AOT-lowered JAX+Pallas network), and report latency / throughput /
//! energy / accuracy — all three stack layers composing on a real small
//! workload.
//!
//!     make artifacts && cargo run --release --example kws_e2e

use cimrv::baselines::OptLevel;
use cimrv::compiler::build_kws_program;
use cimrv::coordinator::{Coordinator, InferenceRequest};
use cimrv::mem::dram::DramConfig;
use cimrv::model::{dataset, KwsModel};
use cimrv::runtime::GoldenModel;
use cimrv::sim::Soc;
use cimrv::util::io::artifacts_dir;

fn main() -> anyhow::Result<()> {
    let model = KwsModel::load_default()?;
    let dir = artifacts_dir()?;
    let eval = dataset::Dataset::load_eval(&dir, model.audio_len, model.n_classes)?;
    let n = 16.min(eval.len());

    // L3: the coordinator with a fleet of simulated chips running the
    // fused resident schedule (weights loaded once, audio-only steady
    // state DRAM traffic).
    let mut coord = Coordinator::start(&model, OptLevel::FUSED, 4)?;
    let reqs: Vec<_> = (0..n)
        .map(|i| InferenceRequest {
            id: i as u64,
            audio: eval.utterance(i).to_vec(),
            label: Some(eval.labels[i]),
            deadline: None,
        })
        .collect();
    let t0 = std::time::Instant::now();
    let resps = coord.serve_batch(reqs)?;
    let wall = t0.elapsed().as_secs_f64();

    // L2/L1: the PJRT golden model (AOT JAX + Pallas kernel via HLO text).
    let golden = GoldenModel::load(&dir)?;
    let mut mismatches = 0;
    for r in &resps {
        let g = golden.infer(eval.utterance(r.id as usize))?;
        if r.logits != g {
            mismatches += 1;
        }
    }

    let cycles: u64 = resps.iter().map(|r| r.chip_cycles).sum();
    let uj: f64 = resps.iter().map(|r| r.energy_uj).sum();
    let correct = resps.iter().filter(|r| r.correct == Some(true)).count();
    println!("served {n} utterances on 4 workers in {wall:.2}s host time");
    println!(
        "chip:  {:.3} ms/inference @50 MHz, {:.2} uJ/inference, {:.1} inf/s chip-rate",
        1e3 * cimrv::clock::cycles_to_seconds(cycles) / n as f64,
        uj / n as f64,
        n as f64 / cimrv::clock::cycles_to_seconds(cycles)
    );
    println!("accuracy: {}/{} ({:.1}%)", correct, n, 100.0 * correct as f64 / n as f64);
    println!(
        "PJRT golden cross-check: {}/{} bit-exact {}",
        n - mismatches,
        n,
        if mismatches == 0 { "✓" } else { "✗" }
    );

    // The fusion win, measured: per-inference DRAM traffic of the fused
    // resident schedule (audio fetch only) vs the full ladder (which
    // re-streams every layer's weights per inference).
    let audio = eval.utterance(0);
    let full_r =
        Soc::new(build_kws_program(&model, OptLevel::FULL)?, DramConfig::default())?.infer(audio)?;
    let fused_r = Soc::new(build_kws_program(&model, OptLevel::FUSED)?, DramConfig::default())?
        .infer(audio)?;
    assert_eq!(full_r.logits, fused_r.logits, "fusion must not change values");
    println!(
        "DRAM traffic/inference: full ladder {} B -> fused resident {} B (-{:.1}%)",
        full_r.energy.dram_bytes,
        fused_r.energy.dram_bytes,
        100.0 * (1.0 - fused_r.energy.dram_bytes as f64 / full_r.energy.dram_bytes as f64)
    );
    coord.shutdown();
    assert_eq!(mismatches, 0, "three-layer stack must agree bit-for-bit");
    Ok(())
}
