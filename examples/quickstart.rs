//! Quickstart: load the trained model, compile it for the chip, run one
//! keyword through the cycle-accurate SoC, and cross-check the logits
//! against the Rust host reference.
//!
//!     make artifacts && cargo run --release --example quickstart

use cimrv::baselines::OptLevel;
use cimrv::compiler::build_kws_program;
use cimrv::mem::dram::DramConfig;
use cimrv::model::{dataset, reference, KwsModel};
use cimrv::sim::Soc;

fn main() -> anyhow::Result<()> {
    // 1. The trained, quantized KWS model exported by `make artifacts`.
    let model = KwsModel::load_default()?;
    println!(
        "model: {} conv layers, {} classes, {} weight bits",
        model.layers.len(),
        model.n_classes,
        model.layers.iter().map(|l| l.weight_bits()).sum::<usize>()
    );

    // 2. Compile the full-stack program (Fig. 10) with all optimizations.
    let program = build_kws_program(&model, OptLevel::FULL)?;
    println!("compiled {} RV32IM+CIM instructions", program.imem.len());

    // 3. Simulate one utterance.
    let audio = dataset::synth_utterance(7, 42, model.audio_len, 0.37);
    let mut soc = Soc::new(program, DramConfig::default())?;
    let result = soc.infer(&audio)?;
    println!("predicted keyword class: {}", result.predicted);
    println!("{}", result.phases.render());
    println!("{}", result.energy.breakdown());

    // 4. Cross-check against the host reference implementation.
    let expected = reference::infer(&model, &audio);
    assert_eq!(result.logits, expected, "simulator must be bit-exact");
    println!("bit-exact against the host reference ✓");
    Ok(())
}
